//! Sharded (and optionally asynchronous) parameter server: the flat
//! gradient is partitioned bucket-aligned across `S` server shards, each
//! shard runs its own reduce loop in a real thread, and workers may run
//! up to `K` rounds ahead of the slowest shard (bounded staleness).
//!
//! Topology per round:
//!
//! 1. **Sharded push.** Every worker cuts its one encoded gradient into
//!    `S` bucket-aligned chunks ([`shard_range`] — pure byte slices via
//!    [`crate::codec::slice_elements_into`], no per-shard
//!    requantization), wraps each in a versioned [`Frame`] carrying the
//!    round number, and pushes all `S` frames before pulling anything —
//!    the shards proceed independently, so a slow shard no longer
//!    serializes the whole round the way the single PS star does.
//! 2. **Per-shard reduce.** Each shard-server thread collects one
//!    upload per worker (accumulating in worker order, in f64 — the
//!    exact [`super::ps::PsCollective`] aggregation restricted to its
//!    chunk), means, encodes the chunk mean (FP by default; requantized
//!    with its own serial codec + RNG stream under `quantize_downlink`,
//!    optionally EF-compensated — TernGrad-style bidirectional
//!    compression), and broadcasts one versioned mean frame to every
//!    worker plus an accounting record to the coordinator. Every decoder
//!    sees the same frame bytes, so the applied mean stays bit-identical
//!    everywhere, lossless or not. With `S = 1` and `K = 0` every decoded
//!    value is bit-identical to [`PsCollective`](super::ps::PsCollective).
//! 3. **Bounded-staleness pull.** At round `r` with window `K`, a worker
//!    blocks only for the mean of round `r − K` (zeros for the first `K`
//!    cold rounds) and *verifies the frame's round field*: any frame
//!    older than `r − K` is a staleness violation and errors out. `K = 0`
//!    is fully synchronous; `K ≥ 1` lets compute of rounds
//!    `r−K+1 ..= r` overlap shard aggregation (round pipelining — the
//!    shard threads really do run ahead of the pulls).
//!
//! Every node (workers and the coordinator) applies the identical mean
//! of round `r − K` at round `r`, so parameter replicas stay bit-identical
//! without parameter traffic — the paper's Algorithm 2 invariant carried
//! over to the stale regime. The deterministic lag also keeps training
//! runs reproducible (same seed ⇒ same parameters for any `S`, `K`).
//!
//! **Streaming.** With `ExchangeConfig::with_streaming` (synchronous,
//! `K = 0` only) workers push one [`FrameKind::Section`] frame per
//! (section, shard) intersection the moment backward stages the section
//! — empty intersections still ship a stamp-only frame so every channel
//! stays in per-round lockstep — and each shard reduces its sections
//! ascending, workers in id order, in f64: the same per-element
//! accumulation order as the flat sharded round, so the assembled mean
//! is bit-identical to it. Sharding a section needs the total element
//! count, which the worker only learns once every section of round 0
//! has been staged: round 0 buffers the pushes and flushes them in
//! [`WorkerExchange::finish_streamed`]; later rounds stream
//! immediately. Each shard's simulated round time is the slowest
//! worker's pipeline recurrence `end = max(end, ready) + transfer`
//! over that worker's frames in send order, plus its mean broadcast.
//!
//! **Accounting.** All sharded-ps edges cross the central aggregation
//! boundary (inter class). Bytes are exact frame sizes; per-shard totals
//! are kept for [`Collective::shard_bytes`]. Simulated time follows the
//! closed-form models in [`super::shard`]: `K = 0` pays the slowest
//! shard's star every round ([`sharded_time`](super::shard::sharded_time)
//! semantics), `K ≥ 1` pays the per-shard bandwidth serially but the
//! latency only once per window ([`async_time`](super::shard::async_time)
//! semantics). The coordinator's [`CommStats`] carries the
//! [`StalenessStats`] applied-version age histogram.
//!
//! **Shutdown.** Shard reduce loops are detached services: they exit
//! when any of their channels disconnects; worker/coordinator ends hold
//! the only senders, so dropping the ends tears the whole topology down
//! without joins that could deadlock (protocol violations travel to the
//! coordinator as a `Failed` record and surface from
//! [`Collective::round`]). When the [`WireSpec`] carries a shared
//! worker pool ([`PoolMode::Shared`](super::collective::PoolMode) — the
//! trainer and `run_rounds` default), the loops run on pool workers via
//! [`spawn_detached`](crate::quant::pool::WorkerPool::spawn_detached)
//! instead of freshly spawned threads, and the collective holds a pool
//! handle that it drops *after* its channels, so the pool's final join
//! never waits on a still-serving shard.

use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

use super::collective::{Collective, CommStats, GradCodec, WireSpec, WorkerExchange};
use super::link::{Link, LinkMap, TrafficMeter};
use super::ps::SECTION_MSG_OFFSET;
use super::shard::{
    begin_frame_into, encode_frame_into, finish_frame, parse_frame, shard_range,
    sharded_time, split_section_payload, Frame, FrameKind, StalenessStats,
};
use crate::codec::{self, DecodeScratch};
use crate::error::{Error, Result};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::error_feedback::ErrorFeedback;
use crate::tensor::rng::Rng;

/// Per-round accounting record a shard sends the coordinator.
enum ShardRecord {
    Round {
        round: u64,
        /// Frame bytes of each worker's upload, indexed by worker id
        /// (flat rounds; streamed rounds carry one entry per frame).
        up_bytes: Vec<usize>,
        /// Streamed rounds only: per-frame (readiness stamp, frame
        /// bytes) rows, `nsec` per worker in the worker's send order,
        /// indexed `worker * nsec + arrival`. Empty in flat rounds.
        stream: Vec<(f64, usize)>,
        /// The broadcast mean frame (the coordinator decodes the same
        /// bytes the workers decode — bit-identical means everywhere).
        frame: Vec<u8>,
    },
    /// A protocol violation (malformed frame, shape mismatch) detected
    /// inside the shard thread; surfaces from [`Collective::round`].
    Failed(Error),
}

/// Seconds to push `bytes` through `link`, bandwidth term only (the
/// async time model accounts latency per staleness window, not per
/// transfer).
fn bw_time(link: &Link, bytes: usize) -> f64 {
    bytes as f64 * 8.0 / link.bandwidth_bps
}

fn check_upload_frame(f: &Frame<'_>, shard: usize, worker: usize, round: u64) -> Result<()> {
    if f.kind != FrameKind::Upload {
        return Err(Error::Comm(format!(
            "shard {shard}: expected an upload frame from worker {worker}, got {:?}",
            f.kind
        )));
    }
    if f.slot as usize != shard {
        return Err(Error::Comm(format!(
            "shard {shard}: frame addressed to shard {}",
            f.slot
        )));
    }
    if f.sender as usize != worker {
        return Err(Error::Comm(format!(
            "shard {shard}: frame from worker {} on worker {worker}'s channel",
            f.sender
        )));
    }
    if f.round != round {
        return Err(Error::Comm(format!(
            "shard {shard}: worker {worker} sent round {} during round {round}",
            f.round
        )));
    }
    Ok(())
}

/// Validate a mean frame pulled by a worker (or asserted by tests) at
/// `round` with staleness window `k` from shard `shard`. Returns the
/// frame's model version (its round). The bounded-staleness guarantee is
/// enforced here: a version older than `round − k` is refused.
fn check_mean_frame(f: &Frame<'_>, shard: usize, round: u64, k: u64) -> Result<u64> {
    if f.kind != FrameKind::Mean {
        return Err(Error::Comm(format!(
            "shard {shard}: expected a mean frame, got {:?}",
            f.kind
        )));
    }
    if f.slot as usize != shard || f.sender as usize != shard {
        return Err(Error::Comm(format!(
            "mean frame from shard {}/sender {} on shard {shard}'s channel",
            f.slot, f.sender
        )));
    }
    let want = round - k; // callers guarantee round ≥ k
    if f.round < want {
        return Err(Error::Comm(format!(
            "staleness violation: shard {shard} served model version {} at round {round} \
             (window {k} admits nothing older than {want})",
            f.round
        )));
    }
    if f.round != want {
        return Err(Error::Comm(format!(
            "out-of-order mean frame: shard {shard} served version {} at round {round}, \
             expected {want}",
            f.round
        )));
    }
    Ok(f.round)
}

// --------------------------------------------------------------------
// Shard reduce thread
// --------------------------------------------------------------------

/// One server shard: owns the per-worker uplink inboxes and downlink
/// senders for its chunk, and reduces rounds back-to-back in its own
/// thread, independent of every other shard.
struct ShardServer {
    shard: usize,
    shards: usize,
    workers: usize,
    uplinks: Vec<Receiver<Vec<u8>>>,
    downlinks: Vec<Sender<Vec<u8>>>,
    record_tx: Sender<ShardRecord>,
    round: u64,
    /// `Some(nsec)` = streamed rounds: `nsec` section frames per worker
    /// instead of one chunk upload.
    streaming: Option<usize>,
    /// Requantize the mean downlink with `codec` (serial — the shard
    /// loop may itself run on a pool worker, so pool-in-pool encoding is
    /// off the table; wire bytes are thread-count invariant anyway).
    quantize_downlink: bool,
    codec: GradCodec,
    down_ef: Option<ErrorFeedback>,
    rng_down: Rng,
    qg: QuantizedGrad,
    acc: Vec<f64>,
    flat: Vec<f32>,
    mean: Vec<f32>,
    payload: Vec<u8>,
    scratch: DecodeScratch,
    recorder: crate::obs::TraceRecorder,
}

impl ShardServer {
    fn run(mut self) {
        loop {
            match self.serve_round() {
                Ok(true) => {}
                // A peer hung up: the run is over (or aborting); exit and
                // drop our senders so everyone else unblocks too.
                Ok(false) => return,
                Err(e) => {
                    let _ = self.record_tx.send(ShardRecord::Failed(e));
                    return;
                }
            }
        }
    }

    /// Flat gather: one chunk upload per worker, accumulated into
    /// `self.acc` in worker-id order — the `PsCollective` aggregation
    /// restricted to this shard's chunk. `Ok(false)` = disconnect.
    fn gather_flat(&mut self, r: u64, up_bytes: &mut Vec<usize>) -> Result<bool> {
        let mut chunk_len: Option<usize> = None;
        self.acc.clear();
        for w in 0..self.workers {
            let bytes = match self.uplinks[w].recv() {
                Ok(b) => b,
                Err(_) => return Ok(false),
            };
            up_bytes.push(bytes.len());
            let f = parse_frame(&bytes)?;
            check_upload_frame(&f, self.shard, w, r)?;
            codec::decode_flat_into(f.payload, &mut self.flat, &mut self.scratch)?;
            match chunk_len {
                None => {
                    chunk_len = Some(self.flat.len());
                    self.acc.resize(self.flat.len(), 0.0);
                }
                Some(n) if n != self.flat.len() => {
                    return Err(Error::Shape(format!(
                        "shard {}: worker {w} chunk has {} elements, expected {n}",
                        self.shard,
                        self.flat.len()
                    )))
                }
                Some(_) => {}
            }
            for (a, v) in self.acc.iter_mut().zip(&self.flat) {
                *a += *v as f64;
            }
        }
        Ok(true)
    }

    /// Streamed gather: `nsec` section frames per worker (each worker's
    /// channel delivers them in its send order), then accumulate into
    /// `self.acc` sections ascending, workers in id order — the same
    /// per-element order as [`Self::gather_flat`], so the chunk mean is
    /// bit-identical to the flat round's. Section∩chunk slices tile the
    /// chunk contiguously in section order, so offsets come from the
    /// slices' own lengths. `Ok(false)` = disconnect.
    fn gather_sections(
        &mut self,
        nsec: usize,
        r: u64,
        up_bytes: &mut Vec<usize>,
        stream: &mut Vec<(f64, usize)>,
    ) -> Result<bool> {
        let mut slots: Vec<Option<Vec<u8>>> = (0..self.workers * nsec).map(|_| None).collect();
        for w in 0..self.workers {
            for _ in 0..nsec {
                let bytes = match self.uplinks[w].recv() {
                    Ok(b) => b,
                    Err(_) => return Ok(false),
                };
                let sec = {
                    let f = parse_frame(&bytes)?;
                    if f.kind != FrameKind::Section {
                        return Err(Error::Comm(format!(
                            "shard {}: expected a section frame from worker {w}, got {:?}",
                            self.shard, f.kind
                        )));
                    }
                    if f.sender as usize != w {
                        return Err(Error::Comm(format!(
                            "shard {}: frame from worker {} on worker {w}'s channel",
                            self.shard, f.sender
                        )));
                    }
                    if f.round != r {
                        return Err(Error::Comm(format!(
                            "shard {}: worker {w} sent round {} during round {r}",
                            self.shard, f.round
                        )));
                    }
                    let sec = f.slot as usize;
                    if sec >= nsec {
                        return Err(Error::Comm(format!(
                            "shard {}: section {sec} out of range ({nsec} sections)",
                            self.shard
                        )));
                    }
                    let (ready, _msg) = split_section_payload(f.payload)?;
                    stream.push((ready, bytes.len()));
                    sec
                };
                if slots[w * nsec + sec].is_some() {
                    return Err(Error::Comm(format!(
                        "shard {}: duplicate section {sec} from worker {w}",
                        self.shard
                    )));
                }
                up_bytes.push(bytes.len());
                slots[w * nsec + sec] = Some(bytes);
            }
        }
        self.acc.clear();
        let mut offset = 0usize;
        for sec in 0..nsec {
            let mut sec_len: Option<usize> = None;
            for w in 0..self.workers {
                let bytes = slots[w * nsec + sec].as_ref().expect("one frame per slot");
                let msg = &bytes[SECTION_MSG_OFFSET..];
                // Stamp-only frame: this section misses the chunk.
                let len = if msg.is_empty() {
                    0
                } else {
                    codec::decode_flat_into(msg, &mut self.flat, &mut self.scratch)?;
                    self.flat.len()
                };
                match sec_len {
                    None => {
                        sec_len = Some(len);
                        self.acc.resize(offset + len, 0.0);
                    }
                    Some(n) if n != len => {
                        return Err(Error::Shape(format!(
                            "shard {}: worker {w} sent {len} elements for section {sec}, \
                             expected {n}",
                            self.shard
                        )))
                    }
                    Some(_) => {}
                }
                if len > 0 {
                    for (a, v) in self.acc[offset..].iter_mut().zip(&self.flat) {
                        *a += *v as f64;
                    }
                }
            }
            offset += sec_len.unwrap_or(0);
        }
        Ok(true)
    }

    /// Serve one round. `Ok(false)` = a channel disconnected (clean
    /// shutdown); `Err` = protocol violation to report.
    fn serve_round(&mut self) -> Result<bool> {
        let r = self.round;
        // Each shard runs in its own thread, so wall-clock spans on its
        // own track are race-free; the gather span includes the blocking
        // wait for the slowest worker's upload.
        let fine = self.recorder.is_fine();
        let track = crate::obs::Track::Shard(self.shard as u16);
        let mut up_bytes = Vec::with_capacity(self.workers);
        let mut stream = Vec::new();
        if fine {
            self.recorder.begin(track, "shard_gather");
        }
        let gathered = match self.streaming {
            Some(nsec) => self.gather_sections(nsec, r, &mut up_bytes, &mut stream),
            None => self.gather_flat(r, &mut up_bytes),
        };
        if fine {
            self.recorder.end(track, "shard_gather");
        }
        if !gathered? {
            return Ok(false);
        }
        // An empty chunk means the bucket grid is cut finer than it has
        // buckets (shards > ⌈n / d⌉) — reject with the actionable error
        // instead of serving dead air. (The trainer pre-checks this;
        // run_once-style drivers get the message through the
        // coordinator's round.)
        if self.acc.is_empty() && self.shards > 1 {
            return Err(Error::InvalidArg(format!(
                "sharded-ps shard {} owns no elements: shards ({}) exceeds \
                 the gradient's bucket count; every shard must own at least \
                 one bucket — reduce --shards or --bucket",
                self.shard, self.shards
            )));
        }
        let inv = 1.0 / self.workers as f64;
        if fine {
            self.recorder.begin(track, "shard_reduce");
        }
        self.mean.clear();
        self.mean.extend(self.acc.iter().map(|a| (*a * inv) as f32));
        // Encode the chunk mean once; workers and the coordinator decode
        // the identical frame bytes, so the applied mean is bit-identical
        // everywhere whether the downlink is lossless FP or requantized.
        if self.quantize_downlink && !self.codec.is_fp() && !self.mean.is_empty() {
            match &mut self.down_ef {
                Some(ef) => self.codec.encode_ef_into(
                    ef,
                    &self.mean,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.payload,
                ),
                None => self.codec.encode_into(
                    &self.mean,
                    &mut self.rng_down,
                    &mut self.qg,
                    &mut self.payload,
                ),
            }
        } else {
            codec::encode_fp_into(&self.mean, &mut self.payload);
        }
        let mut frame = Vec::new();
        encode_frame_into(
            FrameKind::Mean,
            r,
            self.shard as u16,
            self.shard as u16,
            &self.payload,
            &mut frame,
        );
        if fine {
            self.recorder.end(track, "shard_reduce");
            self.recorder.begin(track, "shard_broadcast");
        }
        for tx in &self.downlinks {
            if tx.send(frame.clone()).is_err() {
                if fine {
                    self.recorder.end(track, "shard_broadcast");
                }
                return Ok(false);
            }
        }
        if fine {
            self.recorder.end(track, "shard_broadcast");
        }
        if self.record_tx.send(ShardRecord::Round { round: r, up_bytes, stream, frame }).is_err()
        {
            return Ok(false);
        }
        self.round += 1;
        Ok(true)
    }
}

// --------------------------------------------------------------------
// Coordinator
// --------------------------------------------------------------------

/// Coordinator end of the sharded/async parameter server: per-round byte
/// and critical-path accounting, the staleness histogram, and the same
/// lag-`K` mean application the workers perform (so the trainer's server
/// replica stays bit-identical to the worker replicas).
pub struct ShardedPsCollective {
    workers: usize,
    shards: usize,
    staleness: u64,
    streaming: Option<usize>,
    link: Link,
    record_rxs: Vec<Receiver<ShardRecord>>,
    meter: TrafficMeter,
    round: u64,
    /// K = 0 critical path: Σ_rounds max_shards (slowest uplink + bcast).
    sim_sync_s: f64,
    /// K = 0 closed-form model: Σ_rounds [`sharded_time`] on the round's
    /// observed byte totals (mean chunk vs slowest chunk — a genuine but
    /// small error when the bucket grid splits raggedly across shards).
    /// Streamed rounds mirror the recurrence, so their drift measures
    /// accounting consistency. For K ≥ 1 the reported sim time *is* the
    /// `async_time` closed form, so model and sim coincide by definition.
    model_sync_s: f64,
    /// K ≥ 1 critical path: per-shard cumulative bandwidth-only busy time
    /// (latency is paid per staleness window, see `stats`).
    shard_bw_s: Vec<f64>,
    /// Exact wire bytes through each shard (uplinks + broadcast).
    per_shard_bytes: Vec<u64>,
    staleness_stats: StalenessStats,
    /// Assembled round means not yet applied (at most K + 1 in flight).
    ready: VecDeque<Vec<f32>>,
    pool: Vec<Vec<f32>>,
    chunk: Vec<f32>,
    scratch: DecodeScratch,
    /// Keeps the shared worker pool hosting the shard reduce loops alive
    /// for as long as this collective. Declared last: Rust drops fields
    /// in declaration order, so the channels above disconnect (shard
    /// loops exit) before a final pool handle could start joining.
    _worker_pool: Option<crate::quant::pool::PoolHandle>,
}

impl ShardedPsCollective {
    /// Build the sharded topology and spawn one detached reduce thread
    /// per shard. All sharded-ps edges cross the central aggregation
    /// boundary, so the star uses the *inter* link.
    pub fn new(
        workers: usize,
        shards: usize,
        staleness: usize,
        links: LinkMap,
        spec: &WireSpec,
        quantize_downlink: bool,
        error_feedback: bool,
        streaming: Option<usize>,
    ) -> Result<(ShardedPsCollective, Vec<ShardedPsWorker>)> {
        if workers == 0 {
            return Err(Error::InvalidArg(
                "sharded parameter server needs at least 1 worker".into(),
            ));
        }
        if shards == 0 {
            return Err(Error::InvalidArg(
                "sharded parameter server needs at least 1 shard".into(),
            ));
        }
        if streaming.is_some() && staleness != 0 {
            return Err(Error::InvalidArg(
                "section streaming requires a synchronous sharded PS (staleness 0)".into(),
            ));
        }
        if workers > u16::MAX as usize || shards > u16::MAX as usize {
            return Err(Error::InvalidArg(format!(
                "sharded-ps frames address at most {} workers/shards (got {workers}/{shards})",
                u16::MAX
            )));
        }
        // Validate the wire spec (quantizer name) up front, the
        // build_topology contract shared by every topology.
        let _ = GradCodec::new(spec)?;
        // Downlink codecs are serial clones of the spec: the shard loops
        // may themselves run on pool workers (no pool-in-pool encodes),
        // and serial wire bytes are identical to any parallel count.
        let down_spec = {
            let mut s = spec.clone();
            s.threads = 1;
            s.pool = super::collective::PoolMode::Scoped;
            s
        };

        // Per-(shard, worker) uplink and downlink channels: dedicated
        // edges keep each channel FIFO-in-round-order per worker, which
        // is what lets shards and workers validate rounds without a
        // reorder buffer.
        let mut shard_uplinks: Vec<Vec<Receiver<Vec<u8>>>> = Vec::with_capacity(shards);
        let mut shard_downlinks: Vec<Vec<Sender<Vec<u8>>>> = Vec::with_capacity(shards);
        let mut worker_uplinks: Vec<Vec<Sender<Vec<u8>>>> =
            (0..workers).map(|_| Vec::with_capacity(shards)).collect();
        let mut worker_downlinks: Vec<Vec<Receiver<Vec<u8>>>> =
            (0..workers).map(|_| Vec::with_capacity(shards)).collect();
        for _s in 0..shards {
            let mut ups = Vec::with_capacity(workers);
            let mut downs = Vec::with_capacity(workers);
            for w in 0..workers {
                let (utx, urx) = channel::<Vec<u8>>();
                let (dtx, drx) = channel::<Vec<u8>>();
                worker_uplinks[w].push(utx);
                worker_downlinks[w].push(drx);
                ups.push(urx);
                downs.push(dtx);
            }
            shard_uplinks.push(ups);
            shard_downlinks.push(downs);
        }

        let mut record_rxs = Vec::with_capacity(shards);
        for (s, (uplinks, downlinks)) in
            shard_uplinks.into_iter().zip(shard_downlinks).enumerate()
        {
            let (record_tx, record_rx) = channel::<ShardRecord>();
            record_rxs.push(record_rx);
            let codec = GradCodec::new(&down_spec)?;
            let down_ef = (error_feedback && quantize_downlink && !codec.is_fp())
                .then(|| codec.error_feedback());
            let server = ShardServer {
                shard: s,
                shards,
                workers,
                uplinks,
                downlinks,
                record_tx,
                round: 0,
                streaming,
                quantize_downlink,
                codec,
                down_ef,
                rng_down: Rng::stream(spec.seed, 7_000 + s as u64),
                qg: QuantizedGrad::default(),
                acc: Vec::new(),
                flat: Vec::new(),
                mean: Vec::new(),
                payload: Vec::new(),
                scratch: DecodeScratch::default(),
                recorder: spec.recorder.clone(),
            };
            // Detached on purpose: the loop exits as soon as any of its
            // channels disconnects, so no join (which could deadlock a
            // mid-error teardown) is ever needed. With a shared worker
            // pool the loop runs on a (reusable) pool worker; otherwise
            // it gets a dedicated named thread as in PR 4.
            match spec.pool.shared() {
                Some(pool) => pool.spawn_detached(move || server.run())?,
                None => {
                    let _ = std::thread::Builder::new()
                        .name(format!("orq-shard-{s}"))
                        .spawn(move || server.run())?;
                }
            }
        }

        let k = staleness as u64;
        let ends = worker_uplinks
            .into_iter()
            .zip(worker_downlinks)
            .enumerate()
            .map(|(w, (up_txs, down_rxs))| ShardedPsWorker {
                id: w,
                shards,
                staleness: k,
                bucket: spec.bucket_size,
                streaming,
                up_txs,
                down_rxs,
                round: 0,
                n: None,
                sec_lens: Vec::new(),
                buffered: Vec::new(),
                chunk: Vec::new(),
                scratch: DecodeScratch::default(),
                recorder: spec.recorder.clone(),
            })
            .collect();
        Ok((
            ShardedPsCollective {
                workers,
                shards,
                staleness: k,
                streaming,
                link: links.inter,
                record_rxs,
                meter: TrafficMeter::default(),
                round: 0,
                sim_sync_s: 0.0,
                model_sync_s: 0.0,
                shard_bw_s: vec![0.0; shards],
                per_shard_bytes: vec![0; shards],
                staleness_stats: StalenessStats::default(),
                ready: VecDeque::new(),
                pool: Vec::new(),
                chunk: Vec::new(),
                scratch: DecodeScratch::default(),
                _worker_pool: spec.pool.shared().cloned(),
            },
            ends,
        ))
    }
}

impl Collective for ShardedPsCollective {
    fn num_workers(&self) -> usize {
        self.workers
    }

    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let t = self.round;
        let mut assembled = self.pool.pop().unwrap_or_default();
        assembled.clear();
        let mut round_time = 0.0f64;
        let mut round_up_bytes = 0u64;
        let mut round_down_bytes = 0u64;
        for s in 0..self.shards {
            let rec = self.record_rxs[s].recv().map_err(|_| {
                Error::Comm(format!("sharded-ps shard {s} died mid-round"))
            })?;
            let (round, up_bytes, stream, frame) = match rec {
                ShardRecord::Failed(e) => return Err(e),
                ShardRecord::Round { round, up_bytes, stream, frame } => {
                    (round, up_bytes, stream, frame)
                }
            };
            if round != t {
                return Err(Error::Comm(format!(
                    "sharded-ps shard {s} reported round {round} during round {t}"
                )));
            }
            let mut up_max = 0.0f64;
            let mut up_bw_max = 0.0f64;
            for &b in &up_bytes {
                self.meter.record_up(&self.link, b);
                self.per_shard_bytes[s] += b as u64;
                round_up_bytes += b as u64;
                up_max = up_max.max(self.link.transfer_time(b));
                up_bw_max = up_bw_max.max(bw_time(&self.link, b));
            }
            if let Some(nsec) = self.streaming {
                // Streamed uplink: the shard's gate is the slowest
                // worker's pipeline recurrence over its own frames in
                // send order, measured from the round's backward start.
                if stream.len() != self.workers * nsec {
                    return Err(Error::Comm(format!(
                        "sharded-ps shard {s} reported {} stream rows, expected {}",
                        stream.len(),
                        self.workers * nsec
                    )));
                }
                up_max = 0.0;
                for rows in stream.chunks_exact(nsec) {
                    let mut end = 0.0f64;
                    for &(ready, b) in rows {
                        end = end.max(ready) + self.link.transfer_time(b);
                    }
                    up_max = up_max.max(end);
                }
            }
            // Broadcast counted once per shard (the PS multicast
            // convention).
            self.meter.record_down(&self.link, frame.len());
            self.per_shard_bytes[s] += frame.len() as u64;
            round_down_bytes += frame.len() as u64;
            round_time = round_time.max(up_max + self.link.transfer_time(frame.len()));
            self.shard_bw_s[s] += up_bw_max + bw_time(&self.link, frame.len());
            // Decode the same broadcast bytes the workers decode; shard
            // ranges are contiguous and increasing, so concatenation in
            // shard order reassembles the full mean.
            let f = parse_frame(&frame)?;
            codec::decode_flat_into(f.payload, &mut self.chunk, &mut self.scratch)?;
            assembled.extend_from_slice(&self.chunk);
        }
        self.sim_sync_s += round_time;
        if self.streaming.is_some() {
            self.model_sync_s += round_time;
        } else {
            // Per-worker upload (the model's `up_bytes` is one worker's
            // full quantized gradient, sliced evenly across shards).
            let up = (round_up_bytes / self.workers as u64) as usize;
            self.model_sync_s +=
                sharded_time(&self.link, self.workers, self.shards, up, round_down_bytes as usize);
        }
        self.ready.push_back(assembled);
        mean_out.clear();
        if t >= self.staleness {
            let mean = self.ready.pop_front().expect("K + 1 means buffered");
            mean_out.extend_from_slice(&mean);
            self.pool.push(mean);
            self.staleness_stats.record(self.staleness);
        } else {
            // Cold round: no model version inside the window yet — every
            // node applies the zero mean of the right shape.
            let n = self.ready.front().map(|m| m.len()).unwrap_or(0);
            mean_out.resize(n, 0.0);
            self.staleness_stats.record_cold();
        }
        self.round += 1;
        Ok(())
    }

    fn stats(&self) -> CommStats {
        let (sim_time_s, model_time_s) = if self.staleness == 0 {
            (self.sim_sync_s, self.model_sync_s)
        } else {
            // Pipelined: shards serve rounds back-to-back (bandwidth paid
            // in full on the slowest shard), latency once per window —
            // the async_time model with measured per-frame bytes. The sim
            // time *is* the closed form here, so the model coincides.
            let bw = self.shard_bw_s.iter().cloned().fold(0.0, f64::max);
            let barriers = self.round.div_ceil(self.staleness + 1);
            let t = bw + barriers as f64 * 2.0 * self.link.latency_s;
            (t, t)
        };
        CommStats {
            wire_bytes: self.meter.total_bytes(),
            wire_bytes_intra: 0,
            wire_bytes_inter: self.meter.total_bytes(),
            wire_bytes_up: self.meter.bytes_up,
            wire_bytes_down: self.meter.bytes_down,
            sim_time_s,
            model_time_s,
            messages: self.meter.messages,
            staleness: self.staleness_stats,
        }
    }

    fn shard_bytes(&self) -> Option<Vec<u64>> {
        Some(self.per_shard_bytes.clone())
    }
}

// --------------------------------------------------------------------
// Worker end
// --------------------------------------------------------------------

/// Worker end: slice-and-push to every shard, then pull (only) the
/// round-`r − K` mean frames and reassemble. Chunk/decode scratch is
/// reused across rounds. In streaming mode each staged section is
/// sliced across the shards the moment it arrives — except round 0,
/// which buffers until the total element count is known.
pub struct ShardedPsWorker {
    id: usize,
    shards: usize,
    staleness: u64,
    bucket: usize,
    streaming: Option<usize>,
    up_txs: Vec<Sender<Vec<u8>>>,
    down_rxs: Vec<Receiver<Vec<u8>>>,
    round: u64,
    n: Option<usize>,
    /// Streamed layout learned in round 0: element count per section.
    sec_lens: Vec<usize>,
    /// Round-0 pushes parked until the layout is known:
    /// (section, standalone message, readiness stamp), in push order.
    buffered: Vec<(usize, Vec<u8>, f64)>,
    chunk: Vec<f32>,
    scratch: DecodeScratch,
    recorder: crate::obs::TraceRecorder,
}

impl ShardedPsWorker {
    /// Slice one staged section across every shard and push the frames.
    /// Empty intersections ship a stamp-only frame so each (shard,
    /// worker) channel sees exactly `nsec` frames per round.
    fn send_section_frames(&self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let n = self.n.expect("layout known before streaming frames");
        let sec_start: usize = self.sec_lens[..section].iter().sum();
        let sec_end = sec_start + self.sec_lens[section];
        for s in 0..self.shards {
            let range = shard_range(n, self.bucket, self.shards, s);
            let lo = range.start.max(sec_start);
            let hi = range.end.min(sec_end);
            let mut frame = Vec::new();
            begin_frame_into(
                FrameKind::Section,
                self.round,
                section as u16,
                self.id as u16,
                &mut frame,
            );
            frame.extend_from_slice(&ready_s.to_le_bytes());
            if hi > lo {
                codec::slice_elements_append(payload, lo - sec_start, hi - sec_start, &mut frame)?;
            }
            finish_frame(&mut frame);
            self.up_txs[s]
                .send(frame)
                .map_err(|_| Error::Comm(format!("sharded-ps shard {s} hung up")))?;
        }
        Ok(())
    }
}

impl WorkerExchange for ShardedPsWorker {
    fn id(&self) -> usize {
        self.id
    }

    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()> {
        if self.streaming.is_some() {
            return Err(Error::InvalidArg(
                "this sharded-ps exchange streams sections; use push_section/finish_streamed"
                    .into(),
            ));
        }
        let (n, _) = codec::peek_shape(encoded)?;
        match self.n {
            // Shards-vs-bucket-count validation lives server-side (the
            // shard that would own zero buckets reports the actionable
            // error through the coordinator); erroring here instead would
            // starve the shards and mask the message.
            None => self.n = Some(n),
            Some(m) if m != n => {
                return Err(Error::Shape(format!(
                    "worker {} gradient has {n} elements, previous rounds had {m}",
                    self.id
                )))
            }
            Some(_) => {}
        }
        let r = self.round;
        // ---- push one chunk frame to every shard, before any pull ----
        // Header first, sliced payload appended straight behind it: one
        // payload copy into the one owned buffer the channel must take.
        for s in 0..self.shards {
            let range = shard_range(n, self.bucket, self.shards, s);
            let mut frame = Vec::new();
            begin_frame_into(FrameKind::Upload, r, s as u16, self.id as u16, &mut frame);
            codec::slice_elements_append(encoded, range.start, range.end, &mut frame)?;
            finish_frame(&mut frame);
            self.up_txs[s]
                .send(frame)
                .map_err(|_| Error::Comm(format!("sharded-ps shard {s} hung up")))?;
        }
        // ---- pull the round-(r − K) mean, or zeros while cold ----
        mean_out.clear();
        mean_out.resize(n, 0.0);
        if r >= self.staleness {
            let fine = self.recorder.is_fine();
            let wait_from = fine.then(|| self.recorder.now_us());
            for s in 0..self.shards {
                let bytes = self.down_rxs[s].recv().map_err(|_| {
                    Error::Comm(format!("sharded-ps shard {s} hung up before its mean"))
                })?;
                if let Some(from) = wait_from.filter(|_| s == 0) {
                    // Wall time this worker blocked on the first (and so
                    // the gating) mean frame of its staleness window.
                    self.recorder.counter(
                        crate::obs::Track::Worker(self.id as u16),
                        "staleness_wait_us",
                        (self.recorder.now_us() - from) as f64,
                    );
                }
                let f = parse_frame(&bytes)?;
                check_mean_frame(&f, s, r, self.staleness)?;
                codec::decode_flat_into(f.payload, &mut self.chunk, &mut self.scratch)?;
                let range = shard_range(n, self.bucket, self.shards, s);
                if self.chunk.len() != range.len() {
                    return Err(Error::Shape(format!(
                        "shard {s} mean chunk has {} elements, expected {}",
                        self.chunk.len(),
                        range.len()
                    )));
                }
                mean_out[range].copy_from_slice(&self.chunk);
            }
        }
        self.round += 1;
        Ok(())
    }

    fn push_section(&mut self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this sharded-ps exchange was not built for streaming".into(),
            ));
        };
        if section >= nsec {
            return Err(Error::InvalidArg(format!(
                "section {section} out of range ({nsec} sections)"
            )));
        }
        if !ready_s.is_finite() || ready_s < 0.0 {
            return Err(Error::InvalidArg(format!(
                "readiness stamp must be finite and non-negative, got {ready_s}"
            )));
        }
        if self.n.is_none() {
            // Round 0: the shard cut needs the total element count, which
            // is only known once every section has been staged — park the
            // push; finish_streamed flushes in this order.
            if self.buffered.iter().any(|(s, _, _)| *s == section) {
                return Err(Error::InvalidArg(format!(
                    "duplicate section {section} staged this round"
                )));
            }
            self.buffered.push((section, payload.to_vec(), ready_s));
            return Ok(());
        }
        let (len, _) = codec::peek_shape(payload)?;
        if len != self.sec_lens[section] {
            return Err(Error::Shape(format!(
                "section {section} has {len} elements, round 0 had {}",
                self.sec_lens[section]
            )));
        }
        self.send_section_frames(section, payload, ready_s)
    }

    fn finish_streamed(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this sharded-ps exchange was not built for streaming".into(),
            ));
        };
        if self.n.is_none() {
            // Learn the layout from the buffered round-0 pushes, then
            // flush them in their original (send-schedule) order.
            if self.buffered.len() != nsec {
                return Err(Error::InvalidArg(format!(
                    "round 0 staged {} sections, expected {nsec}",
                    self.buffered.len()
                )));
            }
            let mut lens = vec![None::<usize>; nsec];
            for (sec, payload, _) in &self.buffered {
                let (len, _) = codec::peek_shape(payload)?;
                lens[*sec] = Some(len);
            }
            // Every section present exactly once (duplicates were refused
            // at push time, so all slots are filled here).
            self.sec_lens = lens.into_iter().map(|l| l.expect("one push per section")).collect();
            self.n = Some(self.sec_lens.iter().sum());
            for (sec, payload, ready) in std::mem::take(&mut self.buffered) {
                self.send_section_frames(sec, &payload, ready)?;
            }
        }
        // Streaming is synchronous (K = 0): pull this round's mean.
        let r = self.round;
        let n = self.n.expect("layout set above");
        mean_out.clear();
        mean_out.resize(n, 0.0);
        let fine = self.recorder.is_fine();
        let wait_from = fine.then(|| self.recorder.now_us());
        for s in 0..self.shards {
            let bytes = self.down_rxs[s].recv().map_err(|_| {
                Error::Comm(format!("sharded-ps shard {s} hung up before its mean"))
            })?;
            if let Some(from) = wait_from.filter(|_| s == 0) {
                self.recorder.counter(
                    crate::obs::Track::Worker(self.id as u16),
                    "staleness_wait_us",
                    (self.recorder.now_us() - from) as f64,
                );
            }
            let f = parse_frame(&bytes)?;
            check_mean_frame(&f, s, r, 0)?;
            codec::decode_flat_into(f.payload, &mut self.chunk, &mut self.scratch)?;
            let range = shard_range(n, self.bucket, self.shards, s);
            if self.chunk.len() != range.len() {
                return Err(Error::Shape(format!(
                    "shard {s} mean chunk has {} elements, expected {}",
                    self.chunk.len(),
                    range.len()
                )));
            }
            mean_out[range].copy_from_slice(&self.chunk);
        }
        self.round += 1;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::{run_once, ExchangeConfig, Topology};
    use crate::quant::bucket::QuantizedGrad;
    use crate::tensor::rng::Rng;

    fn links() -> LinkMap {
        LinkMap::uniform(Link::ten_gbps())
    }

    fn gaussian(n: usize, seed: u64) -> Vec<f32> {
        let mut rng = Rng::seed_from(seed);
        (0..n).map(|_| rng.gaussian_f32()).collect()
    }

    #[test]
    fn new_rejects_degenerate_builds() {
        let spec = WireSpec::new("terngrad", 64);
        assert!(ShardedPsCollective::new(0, 1, 0, links(), &spec, false, false, None).is_err());
        assert!(ShardedPsCollective::new(2, 0, 0, links(), &spec, false, false, None).is_err());
        assert!(
            ShardedPsCollective::new(70_000, 1, 0, links(), &spec, false, false, None).is_err()
        );
        let bad = WireSpec::new("bogus", 64);
        assert!(ShardedPsCollective::new(2, 1, 0, links(), &bad, false, false, None).is_err());
        assert!(ShardedPsCollective::new(2, 2, 1, links(), &spec, false, false, None).is_ok());
        assert!(ShardedPsCollective::new(2, 2, 0, links(), &spec, true, true, None).is_ok());
        // Streaming is synchronous-only; K ≥ 1 is refused at build time.
        assert!(ShardedPsCollective::new(2, 2, 1, links(), &spec, false, false, Some(4)).is_err());
        assert!(ShardedPsCollective::new(2, 2, 0, links(), &spec, false, false, Some(4)).is_ok());
    }

    #[test]
    fn upload_and_mean_frame_checks() {
        let payload = crate::codec::encode_fp(&[1.0f32, 2.0]);
        let mut bytes = Vec::new();
        encode_frame_into(FrameKind::Upload, 5, 2, 3, &payload, &mut bytes);
        let f = parse_frame(&bytes).unwrap();
        assert!(check_upload_frame(&f, 2, 3, 5).is_ok());
        assert!(check_upload_frame(&f, 1, 3, 5).is_err(), "wrong shard");
        assert!(check_upload_frame(&f, 2, 0, 5).is_err(), "wrong worker");
        assert!(check_upload_frame(&f, 2, 3, 6).is_err(), "wrong round");
        assert!(check_mean_frame(&f, 2, 5, 0).is_err(), "uploads are not means");
    }

    /// The bounded-staleness guarantee lives in `check_mean_frame`: a
    /// version older than `round − K` is refused with a staleness
    /// violation, a newer-but-wrong one as out-of-order.
    #[test]
    fn mean_frame_staleness_bound_enforced() {
        let mk = |round: u64| {
            let mut b = Vec::new();
            encode_frame_into(FrameKind::Mean, round, 1, 1, &[], &mut b);
            b
        };
        let k = 2u64;
        // at round 7 with K = 2, exactly version 5 is admissible
        let ok = mk(5);
        assert_eq!(check_mean_frame(&parse_frame(&ok).unwrap(), 1, 7, k).unwrap(), 5);
        let stale = mk(4);
        let err = check_mean_frame(&parse_frame(&stale).unwrap(), 1, 7, k).unwrap_err();
        assert!(err.to_string().contains("staleness violation"), "{err}");
        let fresh = mk(6);
        assert!(check_mean_frame(&parse_frame(&fresh).unwrap(), 1, 7, k).is_err());
        // K = 0 admits only the current round
        assert!(check_mean_frame(&parse_frame(&mk(7)).unwrap(), 1, 7, 0).is_ok());
        assert!(check_mean_frame(&parse_frame(&mk(6)).unwrap(), 1, 7, 0).is_err());
        // wrong shard id on the channel
        let wrong = mk(5);
        assert!(check_mean_frame(&parse_frame(&wrong).unwrap(), 0, 7, k).is_err());
    }

    #[test]
    fn single_round_fp_mean_matches_ps() {
        let grads = vec![gaussian(1024, 1), gaussian(1024, 2), gaussian(1024, 3)];
        let spec = WireSpec::new("fp", 128);
        let (ps_mean, _) =
            run_once(&ExchangeConfig::flat(Topology::Ps, Link::ten_gbps()), &spec, &grads)
                .unwrap();
        for shards in [1usize, 2, 4] {
            let cfg = ExchangeConfig::sharded(shards, 0, Link::ten_gbps());
            let (mean, st) = run_once(&cfg, &spec, &grads).unwrap();
            assert_eq!(mean, ps_mean, "S={shards}");
            assert_eq!(st.messages, (3 * shards + shards) as u64);
            assert_eq!(st.wire_bytes_intra, 0);
            assert_eq!(st.wire_bytes, st.wire_bytes_inter);
            assert_eq!(st.staleness.rounds, 1);
            assert_eq!(st.staleness.max_age, 0);
        }
    }

    /// Mismatched worker gradient shapes must error out of the round,
    /// not deadlock the scoped join (the PS/hier regression, sharded).
    #[test]
    fn run_once_surfaces_shape_errors_instead_of_hanging() {
        let spec = WireSpec::new("fp", 64);
        let grads = vec![vec![0.5f32; 128], vec![0.5f32; 256]];
        let cfg = ExchangeConfig::sharded(2, 0, Link::ten_gbps());
        assert!(run_once(&cfg, &spec, &grads).is_err());
    }

    /// More shards than buckets: rejected with an actionable error at the
    /// first exchange (every shard must own at least one bucket).
    #[test]
    fn more_shards_than_buckets_rejected() {
        let spec = WireSpec::new("fp", 64);
        let grads = vec![vec![0.5f32; 128]; 2]; // 2 buckets
        let cfg = ExchangeConfig::sharded(3, 0, Link::ten_gbps());
        let err = run_once(&cfg, &spec, &grads).unwrap_err();
        assert!(err.to_string().contains("bucket count"), "{err}");
    }

    /// Drive several rounds by hand: with K = 0 the sync critical path
    /// accumulates per round, and the mean of every round matches the
    /// flat PS mean of the same uploads.
    #[test]
    fn multi_round_sync_means_match_ps() {
        let rounds = 4usize;
        let workers = 3usize;
        let cfg = ExchangeConfig::sharded(2, 0, Link::ten_gbps());
        // fp keeps the per-round reference reproducible (no RNG advance
        // across rounds); quantized-scheme equivalence is pinned down in
        // tests/topology_equivalence.rs.
        let spec = WireSpec::new("fp", 128);
        let (mut coll, ends) = crate::comm::build_topology(&cfg, workers, &spec).unwrap();
        let mut means = Vec::new();
        std::thread::scope(|scope| {
            for (w, mut wx) in ends.into_iter().enumerate() {
                let spec = spec.clone();
                scope.spawn(move || {
                    let mut gc = GradCodec::new(&spec).unwrap();
                    let mut rng = Rng::stream(spec.seed, 2_000 + w as u64);
                    let mut qg = QuantizedGrad::default();
                    let mut msg = Vec::new();
                    let mut mean = Vec::new();
                    for r in 0..rounds {
                        let g = gaussian(1536, (100 * w + r) as u64);
                        gc.encode_into(&g, &mut rng, &mut qg, &mut msg);
                        wx.exchange(&mut msg, &mut mean).unwrap();
                    }
                });
            }
            for _ in 0..rounds {
                let mut m = Vec::new();
                coll.round(&mut m).unwrap();
                means.push(m);
            }
        });
        let st = coll.stats();
        assert_eq!(st.staleness.rounds, rounds as u64);
        assert_eq!(st.staleness.cold_rounds, 0);
        assert!(st.sim_time_s > 0.0);
        // every round's mean equals the flat PS mean of the same uploads
        for (r, mean) in means.iter().enumerate() {
            let gs: Vec<Vec<f32>> =
                (0..workers).map(|w| gaussian(1536, (100 * w + r) as u64)).collect();
            let (want, _) =
                run_once(&ExchangeConfig::flat(Topology::Ps, Link::ten_gbps()), &spec, &gs)
                    .unwrap();
            assert_eq!(mean, &want, "round {r}");
        }
    }
}
