//! Communication substrate: bandwidth/latency link model, simulated
//! parameter-server topology over real channels, and a ring all-reduce
//! cost model.
//!
//! The paper's Table 1 costs gradients at 10 Gbps; all transfer *times*
//! here come from [`Link::transfer_time`] (a simulated clock — nothing
//! sleeps), while the *bytes* come from the exact wire accounting in
//! [`crate::codec`]. The parameter-server exchange itself runs over real
//! `std::sync::mpsc` channels between worker threads and the server
//! (Algorithm 2 of the paper).

pub mod link;
pub mod ps;
pub mod ring;

pub use link::Link;
pub use ps::{ParameterServer, WorkerHandle};
