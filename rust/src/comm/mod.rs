//! Communication substrate: link model, the topology-agnostic
//! [`Collective`] abstraction, and two real implementations of it.
//!
//! The paper's Table 1 costs gradients at 10 Gbps; all transfer *times*
//! here come from [`Link::transfer_time`] (a simulated clock — nothing
//! sleeps), while the *bytes* come from the exact wire accounting in
//! [`crate::codec`]. Both topologies exchange real bytes over real
//! `std::sync::mpsc` channels between worker threads:
//!
//! * **Parameter server** ([`ps`], `--topology ps`) — L workers ⇄ 1
//!   server star (paper Algorithm 2). Round time is the synchronous
//!   critical path `max_l(uplink_l) + broadcast`; the server decodes,
//!   averages in f64, optionally requantizes the downlink (§4 option b),
//!   and broadcasts.
//! * **Ring all-reduce** ([`ring`], `--topology ring`) — the
//!   decentralized alternative the paper mentions. A round is
//!   reduce-scatter + all-gather over per-hop channels, `2·(L−1)` steps;
//!   each reduce-scatter hop performs **decode → partial-reduce →
//!   requantize** (quantized codebooks are not closed under addition),
//!   while all-gather forwards the final encoded chunks unchanged so
//!   every node decodes a bit-identical mean. Chunks align to the
//!   quantization bucket grid; step time is `max` over the L concurrent
//!   transmissions, summed over steps. [`ring`] also keeps the
//!   closed-form cost model ([`ring::allreduce_time`]) that the Table 1
//!   bench prints next to the measured numbers.
//!
//! Pick a topology from the CLI (`orq train --topology ps|ring`), a
//! config file (`topology = "ring"` under `[train]`), or directly via
//! [`TrainConfig::topology`](crate::config::TrainConfig). The trainer is
//! generic over [`Collective`]/[`WorkerExchange`]; [`build_topology`]
//! constructs either end set from a [`Topology`] tag and [`run_once`]
//! drives a single standalone round (benches/tests).

pub mod collective;
pub mod link;
pub mod ps;
pub mod ring;

pub use collective::{
    build_topology, run_once, Collective, CommStats, GradCodec, Topology, WireSpec,
    WorkerExchange,
};
pub use link::Link;
pub use ps::{ParameterServer, PsCollective, PsWorker, WorkerHandle};
pub use ring::{RingAllReduce, RingWorker};
