//! Communication substrate: per-edge-class link model, the
//! topology-agnostic [`Collective`] abstraction, and four real
//! implementations of it.
//!
//! The paper's Table 1 costs gradients at 10 Gbps; all transfer *times*
//! here come from [`Link::transfer_time`] (a simulated clock — nothing
//! sleeps), while the *bytes* come from the exact wire accounting in
//! [`crate::codec`]. Links are a [`LinkMap`] with one [`Link`] per
//! [`link::EdgeClass`]: *intra*-group (fast, rack-local) and
//! *inter*-group (slow, cross-rack). Flat topologies treat every worker
//! as its own group, so all of their edges are inter-class; a uniform
//! map ([`LinkMap::uniform`]) reproduces the paper's homogeneous 10 Gbps
//! star exactly. All topologies exchange real bytes over real
//! `std::sync::mpsc` channels between worker threads:
//!
//! * **Parameter server** ([`ps`], `--topology ps`) — L workers ⇄ 1
//!   server star (paper Algorithm 2). Round time is the synchronous
//!   critical path `max_l(uplink_l) + broadcast`; the server decodes,
//!   averages in f64, optionally requantizes the downlink (§4 option b),
//!   and broadcasts.
//! * **Ring all-reduce** ([`ring`], `--topology ring`) — the
//!   decentralized alternative the paper mentions. A round is
//!   reduce-scatter + all-gather over per-hop channels, `2·(L−1)` steps;
//!   each reduce-scatter hop performs **decode → partial-reduce →
//!   requantize** (quantized codebooks are not closed under addition),
//!   while all-gather forwards the final encoded chunks unchanged so
//!   every node decodes a bit-identical mean. Under `error_feedback`
//!   each hop position keeps its own residual, so per-hop requantization
//!   error is carried into the same hop of the next round instead of
//!   being discarded. Chunks align to the
//!   quantization bucket grid; step time is `max` over the L concurrent
//!   transmissions, summed over steps. [`ring`] also keeps the
//!   closed-form cost model ([`ring::allreduce_time`]) that the Table 1
//!   bench prints next to the measured numbers.
//! * **Hierarchical two-level** ([`hier`], `--topology hier --groups N`)
//!   — workers partitioned into N groups: intra-group ring
//!   reduce-scatter + chunk gather over fast intra edges, group leaders
//!   decode → reduce → requantize over a slow inter-group star, the mean
//!   multicast back down (root → leaders → members) — FP by default, or
//!   requantized *once* at the root under `quantize_downlink` (the root
//!   decodes its own bytes, so every node still applies a bit-identical
//!   mean; with `error_feedback` the root also keeps a downlink
//!   residual, TernGrad-style bidirectional compression). Per-hop
//!   residuals cover every intra-ring and leader-uplink requantization
//!   site when `error_feedback` is on. Localizes most bytes onto the
//!   fast edges ([`CommStats::wire_bytes_intra`] /
//!   [`CommStats::wire_bytes_inter`] keep the split, and
//!   [`CommStats::wire_bytes_up`] / [`CommStats::wire_bytes_down`] the
//!   direction split); [`hier::hier_time`]
//!   is its closed-form critical-path model.
//! * **Sharded / async parameter server** ([`async_ps`] on the
//!   [`shard`] substrate, `--topology sharded-ps --shards S
//!   [--staleness K]`) — the flat gradient partitioned bucket-aligned
//!   across S server shards (each worker's per-shard upload is a pure
//!   byte slice of its one encoded gradient), each shard reducing in its
//!   own real thread so a slow shard no longer serializes the round.
//!   Every message rides a *versioned frame* (round number in the wire
//!   header); with a bounded staleness window K ≥ 1 workers run up to K
//!   rounds ahead of the slowest shard and apply the round-`r − K` mean
//!   at round `r` (K = 0 is fully synchronous, and `S = 1, K = 0` is
//!   bit-identical to the flat PS). Each shard's mean broadcast is FP by
//!   default or requantized once by the shard under `quantize_downlink`
//!   (optionally with a per-shard server-side residual under
//!   `error_feedback`). [`CommStats::staleness`] keeps the
//!   applied-version age histogram; [`shard::sharded_time`] /
//!   [`shard::async_time`] are the closed-form critical-path models.
//!
//! Pick a topology from the CLI (`orq train --topology
//! ps|ring|hier|sharded-ps [--groups N] [--shards S] [--staleness K]`), a
//! config file (`topology = "hier"`, `groups = N`, `topology =
//! "sharded-ps"`, `shards = S`, `staleness = K`, and
//! `intra_bandwidth`/`intra_latency`/`inter_bandwidth`/`inter_latency`
//! under `[train]`), or directly via
//! [`TrainConfig::topology`](crate::config::TrainConfig). The trainer is
//! generic over [`Collective`]/[`WorkerExchange`]; [`build_topology`]
//! constructs any end set from an [`ExchangeConfig`] and [`run_once`]
//! drives a single standalone round (benches/tests).
//!
//! Execution of the parallel codec shards, the sharded-PS reduce loops
//! and the [`run_rounds`] worker loops is governed by
//! [`WireSpec::pool`]/[`PoolMode`]: the default runs everything on one
//! persistent worker pool (`crate::quant::pool`) so thread spawns and
//! per-thread solver arenas amortize across rounds; `PoolMode::Scoped`
//! retains the per-round scoped threads as the measurable baseline.
//! All modes are bit-identical in wire bytes and decoded means.
//!
//! **Backward/communication overlap** ([`overlap`], `--overlap
//! [--sections N]`) — a model-section bucket map ([`SectionMap`]) seeded
//! from the backend's layer structure cuts the codec's bucket grid at
//! layer-group boundaries; the overlap driver ([`OverlapEncoder`])
//! quantizes+encodes each section on the worker pool the moment the
//! reverse-order backward reports it complete, hiding encode latency
//! behind the remaining backward compute. The assembled message is
//! byte-identical to the flat parallel encode, so every topology,
//! thread count, and error-feedback setting trains to bit-identical
//! parameters with overlap on or off. The overlapped closed-form round
//! models ([`overlap::overlap_round_time`] and the per-topology
//! wrappers) extend the flat `ps`/`ring`/`hier`/`sharded` models with
//! the pipeline recurrence `end_i = max(end_{i-1}, ready_i) + comm_i`
//! plus the exposed mean-broadcast tail.
//!
//! **Section streaming** (`--stream-sections`, implies `--overlap`;
//! [`ExchangeConfig::with_streaming`]) — overlap hides *encode* latency
//! but still ships one flat message per round; streaming puts every
//! staged section on the wire the moment its encode completes, as a
//! [`shard::FrameKind::Section`] frame (magic / version / kind /
//! section slot / sender / round / payload length, plus an in-band
//! readiness stamp), so early sections transfer while the backward tail
//! still computes. Workers push frames via
//! [`WorkerExchange::push_section`] in descending section order and
//! complete the round with [`WorkerExchange::finish_streamed`]. Per
//! topology:
//!
//! | topology     | streaming                                       | vs flat overlap               |
//! |--------------|-------------------------------------------------|-------------------------------|
//! | `ps`         | server reduces section frames incrementally     | bit-identical                 |
//! | `sharded-ps` | per-shard section slices (stamp-only when empty); K = 0 only | bit-identical    |
//! | `hier`       | sections stream up the intra ring / leader star | bit-identical                 |
//! | `ring`       | one reduce-scatter + all-gather per section     | deterministic ≡ serial replay |
//!
//! The PS-family paths keep worker-order f64 accumulation per section,
//! so the streamed mean is bit-identical to the flat overlap round; the
//! ring requantizes per (hop, section) — its contract is thread-count
//! determinism (equivalence to the serial replay of the same section
//! schedule), proven by tests. The streamed closed-form models
//! ([`overlap::ps_streamed_time`], [`overlap::sharded_streamed_time`],
//! [`overlap::hier_streamed_time`], [`overlap::ring_streamed_time`])
//! gate section `i`'s transfer at `max(ready_i, link_free)`; the
//! executable collectives reproduce them to < 1% via the per-frame
//! readiness stamps, measured from the round's backward start.
//!
//! **In-band per-bucket widths / byte budgets** (`--byte-budget BYTES
//! [--budget-schedule coarse-to-fine]`) — the byte-budget allocator
//! ([`crate::quant::budget::allocate_widths`]) re-spends the method's
//! bit width per bucket each round, minimizing total quantization
//! variance subject to the configured per-round uplink byte cap
//! (headers and frames included — the trainer pre-subtracts
//! [`budget_frame_overhead`]). The chosen widths are **never assumed by
//! a receiver**: the encoding side writes the per-bucket width table
//! into the wire header (`FLAG_WIDTHS`,
//! [`crate::codec::encode_quantized_header_widths_into`]), every
//! decoder reads and validates it like any other header field
//! (malformed tables are `Err`, not guesses), and every
//! requantize-and-forward hop (ring chunks, hier intra-ring and leader
//! star uplinks) re-encodes at the widths it *captured from the
//! incoming frame* ([`crate::codec::capture_widths`] →
//! [`GradCodec::encode_matched_into`](collective::GradCodec::encode_matched_into)).
//! Bucket-aligned slices carry the matching sub-table and concatenation
//! reproduces the flat table exactly, so shard slices, ring chunks and
//! streamed section frames all stay self-describing. Without a budget
//! the header carries the scheme's fixed `s` and the wire bytes are
//! bit-identical to the pre-budget codec.
//!
//! **Observability** ([`crate::obs`], `--trace out.json --trace-level
//! fine`) — every collective carries the [`WireSpec::recorder`]
//! ([`crate::obs::TraceRecorder`]): coordinators emit simulated-clock
//! spans for their interior steps (PS gather/reduce, ring RS/AG hops,
//! hier legs and multicast steps), sharded-PS shard threads emit
//! wall-clock gather/reduce/broadcast spans on their own tracks, workers
//! get streamed-section readiness/link-start/done instants and
//! staleness-wait counters, and the [`OverlapEncoder`] stamps section
//! staging/push instants. Each collective also accumulates its
//! closed-form model time per round into [`CommStats::model_time_s`] so
//! the metrics export can report measured-vs-model drift (< 1% by
//! contract). Tracing off is one relaxed atomic load per call site and
//! zero allocations — wire bytes and trained parameters are bit-identical
//! with tracing on or off.

// Non-test comm code must not `unwrap()`: dead peers, truncated frames
// and codec failures all surface as `Err` on the coordinator. Provably
// infallible conversions use `expect` with the reason.
#![cfg_attr(not(test), deny(clippy::unwrap_used))]

pub mod async_ps;
pub mod collective;
pub mod hier;
pub mod link;
pub mod overlap;
pub mod ps;
pub mod ring;
pub mod shard;

pub use async_ps::{ShardedPsCollective, ShardedPsWorker};
pub use collective::{
    build_topology, run_once, run_rounds, run_rounds_streamed, Collective, CommStats,
    ExchangeConfig, GradCodec, PoolMode, Topology, WireSpec, WorkerExchange,
};
pub use hier::{HierWorker, HierarchicalCollective};
pub use link::{EdgeClass, Link, LinkMap};
pub use overlap::{
    hier_overlap_time, hier_streamed_time, overlap_round_time, ps_overlap_time, ps_streamed_time,
    ring_overlap_time, ring_streamed_time, sharded_overlap_time, sharded_streamed_time,
    OverlapEncoder, Section, SectionMap, SIM_BACKWARD_RATE,
};
pub use ps::{ParameterServer, PsCollective, PsWorker, WorkerHandle};
pub use ring::{RingAllReduce, RingWorker};
pub use shard::{budget_frame_overhead, StalenessStats};
