//! The `Collective` abstraction: one synchronous gradient exchange per
//! round, independent of topology.
//!
//! A topology has two ends:
//! * [`WorkerExchange`] — one per worker thread. The worker hands in its
//!   *encoded* gradient and blocks until the round's decoded mean
//!   gradient is available. Every worker receives the bit-identical mean,
//!   which is what keeps parameter replicas in sync without ever shipping
//!   parameters (paper Algorithm 2).
//! * [`Collective`] — the coordinator end, driven by the trainer's main
//!   thread. It performs whatever central work the topology needs (the
//!   parameter-server aggregation; for the ring, only bookkeeping),
//!   returns the same decoded mean, and owns the exact wire-byte and
//!   simulated-time accounting ([`CommStats`]).
//!
//! Four real implementations exist, all over `std::sync::mpsc` channels:
//! the star in [`super::ps`], the decode-reduce-requantize ring in
//! [`super::ring`], the two-level hierarchy in [`super::hier`], and the
//! sharded/bounded-staleness parameter server in [`super::async_ps`].
//! [`build_topology`] constructs any of them from an [`ExchangeConfig`]
//! (topology tag + per-edge-class [`LinkMap`] + grouping/sharding), and
//! [`run_once`] drives a single round with scoped threads — the entry
//! point the Table 1 bench and the equivalence tests use.

use std::sync::mpsc::Receiver;

use crate::codec::{self, Packing};
use crate::error::{Error, Result};
use crate::quant::bucket::{BucketQuantizer, QuantizedGrad};
use crate::quant::budget::{self, BudgetSchedule};
use crate::quant::error_feedback::ErrorFeedback;
use crate::quant::parallel::BucketPipeline;
use crate::quant::pool::PoolHandle;
use crate::quant::{self, Quantizer};
use crate::tensor::rng::Rng;

use super::async_ps::ShardedPsCollective;
use super::hier::HierarchicalCollective;
use super::link::{Link, LinkMap};
use super::overlap::{OverlapEncoder, SectionMap, SIM_BACKWARD_RATE};
use super::ps::PsCollective;
use super::ring::RingAllReduce;
use super::shard::StalenessStats;

/// Which gradient-exchange topology to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// L workers ⇄ 1 server star (paper Algorithm 2).
    #[default]
    Ps,
    /// Decentralized ring all-reduce: reduce-scatter + all-gather with
    /// decode → partial-reduce → requantize at every hop.
    Ring,
    /// Two-level hierarchy: intra-group rings + a leader star
    /// (`groups` in [`ExchangeConfig`] sets the partition).
    Hier,
    /// Sharded parameter server: the gradient partitioned bucket-aligned
    /// across `shards` independent server shards, optionally with a
    /// bounded staleness window (`staleness` in [`ExchangeConfig`];
    /// `K = 0` is fully synchronous, `S = 1, K = 0` ≡ [`Topology::Ps`]).
    ShardedPs,
}

impl Topology {
    pub fn parse(s: &str) -> Result<Topology> {
        match s {
            "ps" | "star" => Ok(Topology::Ps),
            "ring" => Ok(Topology::Ring),
            "hier" | "hierarchical" => Ok(Topology::Hier),
            "sharded-ps" | "sharded" => Ok(Topology::ShardedPs),
            other => Err(Error::InvalidArg(format!(
                "unknown topology {other:?} (use ps, ring, hier or sharded-ps)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Topology::Ps => "ps",
            Topology::Ring => "ring",
            Topology::Hier => "hier",
            Topology::ShardedPs => "sharded-ps",
        }
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Topology {
    type Err = Error;

    fn from_str(s: &str) -> Result<Topology> {
        Topology::parse(s)
    }
}

/// Cumulative exchange accounting: exact wire bytes (total and per edge
/// class), simulated communication seconds on the critical path, message
/// count, and — for the sharded/async parameter server — the
/// applied-version staleness histogram.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub wire_bytes: u64,
    /// Bytes that crossed fast intra-group edges. Zero for flat
    /// topologies (every worker is its own group, so all of their edges
    /// are inter-class).
    pub wire_bytes_intra: u64,
    /// Bytes that crossed slow inter-group edges.
    pub wire_bytes_inter: u64,
    /// Bytes that travelled toward the aggregation point (worker
    /// uploads, ring/hier reduce-scatter hops, leader uplinks).
    pub wire_bytes_up: u64,
    /// Bytes that travelled away from it (mean broadcasts/multicasts,
    /// ring all-gather hops). `quantize_downlink` shrinks exactly this
    /// component.
    pub wire_bytes_down: u64,
    pub sim_time_s: f64,
    /// What the closed-form time model (`ps_time`/`allreduce_time`/
    /// `hier_time`/`sharded_time`/the streamed recurrences) predicts for
    /// the same rounds, accumulated alongside [`sim_time_s`]
    /// (Self::sim_time_s). The obs metrics artifact reports the
    /// per-round difference as the model-drift section; the <1%
    /// invariant the tests assert becomes observable in every run.
    pub model_time_s: f64,
    pub messages: u64,
    /// Per-round applied-version age accounting. All-zero for the
    /// synchronous topologies; populated by [`Topology::ShardedPs`]
    /// (every warm round records age `K`, cold start rounds are counted
    /// separately — see [`StalenessStats`]).
    pub staleness: StalenessStats,
}

/// Everything that shapes the exchange *transport* (as opposed to the
/// wire format, which is [`WireSpec`]): topology, worker grouping, and
/// the per-edge-class link model.
#[derive(Debug, Clone)]
pub struct ExchangeConfig {
    pub topology: Topology,
    /// Worker groups for [`Topology::Hier`] (must divide the worker
    /// count). Flat topologies require 1.
    pub groups: usize,
    /// Server shards for [`Topology::ShardedPs`] (each must own at least
    /// one bucket of the gradient). Every other topology requires 1.
    pub shards: usize,
    /// Bounded staleness window `K` for [`Topology::ShardedPs`]: workers
    /// may run up to `K` rounds ahead of the slowest shard and apply the
    /// round-`r − K` mean at round `r`. `0` (required on every other
    /// topology) is fully synchronous.
    pub staleness: usize,
    pub links: LinkMap,
    /// Quantize the mean downlink too (paper §4 option b, TernGrad-style
    /// bidirectional compression): the PS broadcast, the hierarchy's
    /// root → leaders → members multicast, and the sharded server's
    /// per-shard mean frames. The aggregation point encodes the mean
    /// *once* and every node decodes the same bytes, so the bit-identity
    /// invariant is preserved. Rejected on the ring, which has no
    /// broadcast downlink (the all-gather chunks already ride encoded).
    pub quantize_downlink: bool,
    /// Error-compensate every lossy encode inside the topology: per-hop
    /// residuals on the ring/hier decode → reduce → requantize paths
    /// (one [`ErrorFeedback`] per hop position / tree edge, since each
    /// compensates a different signal) and, combined with
    /// `quantize_downlink`, a server-side residual on the mean downlink.
    /// Worker *uplink* EF stays where it always was — in the trainer's
    /// worker loop (or [`run_rounds`]'s drive loop).
    pub error_feedback: bool,
    /// Stream the exchange section by section: workers push one
    /// [`super::shard::FrameKind::Section`] frame per overlap section the
    /// moment its encode is staged ([`WorkerExchange::push_section`]),
    /// instead of one flat message after backward. PS/hier/sharded-PS
    /// reduce the frames incrementally and stay bit-identical to the
    /// flat path; the ring runs one per-section collective (see the
    /// equivalence contract in [`super::overlap`]). Requires a
    /// synchronous exchange (`staleness == 0`).
    pub streaming: bool,
    /// Section count of the streamed round (each worker pushes exactly
    /// this many frames per round, descending index). Only meaningful
    /// with `streaming`.
    pub sections: usize,
}

impl ExchangeConfig {
    /// A flat (ps/ring) topology over a homogeneous link.
    pub fn flat(topology: Topology, link: Link) -> ExchangeConfig {
        ExchangeConfig {
            topology,
            groups: 1,
            shards: 1,
            staleness: 0,
            links: LinkMap::uniform(link),
            quantize_downlink: false,
            error_feedback: false,
            streaming: false,
            sections: 1,
        }
    }

    /// The hierarchical topology with `groups` groups over a
    /// heterogeneous link map.
    pub fn hier(groups: usize, links: LinkMap) -> ExchangeConfig {
        ExchangeConfig {
            topology: Topology::Hier,
            groups,
            shards: 1,
            staleness: 0,
            links,
            quantize_downlink: false,
            error_feedback: false,
            streaming: false,
            sections: 1,
        }
    }

    /// The sharded parameter server with `shards` server shards and a
    /// bounded staleness window of `staleness` rounds, over a homogeneous
    /// link.
    pub fn sharded(shards: usize, staleness: usize, link: Link) -> ExchangeConfig {
        ExchangeConfig {
            topology: Topology::ShardedPs,
            groups: 1,
            shards,
            staleness,
            links: LinkMap::uniform(link),
            quantize_downlink: false,
            error_feedback: false,
            streaming: false,
            sections: 1,
        }
    }

    pub fn with_downlink(mut self, quantize_downlink: bool) -> ExchangeConfig {
        self.quantize_downlink = quantize_downlink;
        self
    }

    pub fn with_error_feedback(mut self, error_feedback: bool) -> ExchangeConfig {
        self.error_feedback = error_feedback;
        self
    }

    /// Builder-style streaming mode: the exchange moves `sections`
    /// section frames per worker per round instead of one flat message.
    pub fn with_streaming(mut self, sections: usize) -> ExchangeConfig {
        self.streaming = true;
        self.sections = sections;
        self
    }

    /// The streamed section count when streaming is on, `None` on the
    /// flat exchange — the form the topology constructors take.
    pub fn streamed_sections(&self) -> Option<usize> {
        self.streaming.then_some(self.sections)
    }

    /// Validate grouping, sharding, downlink and streaming options
    /// against a worker count.
    pub fn validate(&self, workers: usize) -> Result<()> {
        if self.streaming {
            if self.sections == 0 {
                return Err(Error::InvalidArg(
                    "streaming needs at least one section".into(),
                ));
            }
            if self.sections > u16::MAX as usize {
                // The frame's section index is a u16 slot.
                return Err(Error::InvalidArg(format!(
                    "at most {} sections fit the frame header, got {}",
                    u16::MAX,
                    self.sections
                )));
            }
            if self.staleness != 0 {
                return Err(Error::InvalidArg(format!(
                    "section streaming requires a synchronous exchange; bounded \
                     staleness (K = {}) pipelines whole rounds instead \
                     (drop --stream-sections or set staleness 0)",
                    self.staleness
                )));
            }
        }
        if self.topology != Topology::ShardedPs {
            if self.shards != 1 {
                return Err(Error::InvalidArg(format!(
                    "shards ({}) only applies to the sharded-ps topology",
                    self.shards
                )));
            }
            if self.staleness != 0 {
                return Err(Error::InvalidArg(format!(
                    "staleness ({}) requires the asynchronous sharded-ps topology; \
                     the {} topology is synchronous by construction",
                    self.staleness, self.topology
                )));
            }
        }
        match self.topology {
            Topology::ShardedPs => {
                if self.shards == 0 {
                    return Err(Error::InvalidArg(
                        "shards must be >= 1 (1 degenerates to the flat parameter server)"
                            .into(),
                    ));
                }
                if self.groups != 1 {
                    return Err(Error::InvalidArg(format!(
                        "groups ({}) only applies to the hier topology",
                        self.groups
                    )));
                }
            }
            Topology::Hier => {
                if self.groups == 0 || (workers > 0 && workers % self.groups != 0) {
                    return Err(Error::InvalidArg(format!(
                        "groups ({}) must be a positive divisor of the worker count ({workers})",
                        self.groups
                    )));
                }
            }
            Topology::Ring => {
                if self.quantize_downlink {
                    // Refuse rather than silently no-op: the ring has no
                    // broadcast downlink to quantize — the final all-gather
                    // chunks already ride the ring encoded.
                    return Err(Error::InvalidArg(
                        "quantize_downlink quantizes the aggregation point's mean broadcast; \
                         the ring topology has no broadcast downlink \
                         (drop the flag or pick --topology ps, hier or sharded-ps)"
                            .into(),
                    ));
                }
                if self.groups != 1 {
                    return Err(Error::InvalidArg(format!(
                        "groups ({}) only applies to the hier topology",
                        self.groups
                    )));
                }
            }
            Topology::Ps => {
                if self.groups != 1 {
                    return Err(Error::InvalidArg(format!(
                        "groups ({}) only applies to the hier topology",
                        self.groups
                    )));
                }
            }
        }
        Ok(())
    }
}

/// How parallel codec shards, sharded-PS reduce loops and multi-round
/// drivers execute their worker tasks.
#[derive(Debug, Clone, Default)]
pub enum PoolMode {
    /// Persistent worker pool, one per codec/driver (default): thread
    /// spawns and the per-thread level-solver arenas are paid once per
    /// run, not once per round.
    #[default]
    Pooled,
    /// One persistent pool shared across every codec, collective and
    /// driver built from this spec — what [`run_rounds`] and the trainer
    /// set up, so the whole hot path reuses a single thread set.
    Shared(PoolHandle),
    /// Legacy per-round `std::thread::scope` execution (PRs 3–4) —
    /// retained as the same-machine baseline perfbench measures the
    /// pool against. Bit-identical output to the pooled modes.
    Scoped,
}

impl PoolMode {
    /// The shared pool handle, if this mode carries one.
    pub fn shared(&self) -> Option<&PoolHandle> {
        match self {
            PoolMode::Shared(p) => Some(p),
            _ => None,
        }
    }

    pub fn is_scoped(&self) -> bool {
        matches!(self, PoolMode::Scoped)
    }
}

/// Everything a topology needs to know about the wire format: how
/// gradients are quantized and packed, the seed its internal RNG
/// streams derive from (downlink requantization, ring hop
/// requantization), and how many codec threads each node may use.
#[derive(Debug, Clone)]
pub struct WireSpec {
    /// Quantizer name (see [`quant::from_name`]); `"fp"` disables
    /// quantization.
    pub method: String,
    /// Bucket size d; also the ring's chunk-alignment grid.
    pub bucket_size: usize,
    /// `Some(c)` applies ±c·σ clipping before level selection.
    pub clip_factor: Option<f32>,
    pub packing: Packing,
    pub seed: u64,
    /// Codec threads per node. `1` (the default) is the serial legacy
    /// path — single advancing RNG stream across buckets, bit-identical
    /// to the pre-pipeline wire bytes. Any other value routes
    /// quantize+encode and the PS decode+reduce through the parallel
    /// [`BucketPipeline`] with per-bucket RNG streams; the wire bytes are
    /// then identical for every thread count (`0` = auto-detect cores).
    pub threads: usize,
    /// Task execution mode for the parallel codec, the sharded-PS reduce
    /// loops, and [`run_rounds`]: pooled (default), a shared pool, or
    /// the legacy scoped-thread baseline. Wire bytes and decoded means
    /// are bit-identical across all three.
    pub pool: PoolMode,
    /// Span recorder every node built from this spec writes into
    /// (coordinator phases, collective interiors, sharded-PS shard
    /// threads). Defaults to a disabled recorder, whose calls cost one
    /// atomic load; tracing never touches any RNG stream, so wire bytes
    /// stay bit-identical with it on or off.
    pub recorder: crate::obs::TraceRecorder,
}

impl WireSpec {
    pub fn new(method: &str, bucket_size: usize) -> WireSpec {
        WireSpec {
            method: method.to_string(),
            bucket_size,
            clip_factor: None,
            packing: Packing::BaseS,
            seed: 0,
            threads: 1,
            pool: PoolMode::default(),
            recorder: crate::obs::TraceRecorder::off(),
        }
    }

    /// Builder-style codec thread count override.
    pub fn with_threads(mut self, threads: usize) -> WireSpec {
        self.threads = threads;
        self
    }

    /// Builder-style execution mode override (see [`PoolMode`]).
    pub fn with_pool_mode(mut self, pool: PoolMode) -> WireSpec {
        self.pool = pool;
        self
    }

    /// Builder-style span-recorder override: every node built from this
    /// spec traces into `recorder` (see [`crate::obs`]).
    pub fn with_recorder(mut self, recorder: crate::obs::TraceRecorder) -> WireSpec {
        self.recorder = recorder;
        self
    }

    /// Build the parallel pipeline this spec calls for — `None` when
    /// `threads == 1` (the serial legacy path) — honoring the execution
    /// mode. One construction rule for every pipeline in the stack
    /// (worker codecs, the PS server's decode+reduce).
    pub(crate) fn build_pipeline(&self) -> Option<BucketPipeline> {
        match self.threads {
            1 => None,
            t => Some(match &self.pool {
                PoolMode::Pooled => BucketPipeline::new(t),
                PoolMode::Shared(p) => BucketPipeline::with_pool(t, p.clone()),
                PoolMode::Scoped => BucketPipeline::scoped(t),
            }),
        }
    }
}

/// Per-codec adaptive-budget state (see [`crate::quant::budget`]): the
/// per-round allocator inputs plus the width table currently in force.
/// Widths for round `t + 1` are derived from round `t`'s *decoded mean*
/// — a value every node holds bit-identically — so all nodes compute the
/// identical table with zero extra coordination (round 0 uses uniform
/// statistics through the same allocator). The table still travels
/// in-band on every message; receiving hops re-encode at the widths they
/// *decode* from the frame ([`GradCodec::encode_matched_into`]), never
/// at the ones they would derive.
struct BudgetState {
    /// Allocator byte budget per full-gradient uplink stream — the
    /// configured `byte_budget` minus the topology's framing overhead
    /// ([`super::shard::budget_frame_overhead`]), subtracted up front so
    /// the wire spend including all headers stays ≤ the configured value.
    budget_bytes: usize,
    schedule: Option<BudgetSchedule>,
    /// Widths range over `2..=s_max` — the configured method's level
    /// count is the ceiling.
    s_max: usize,
    /// Current round's width table (empty until first use; recomputed by
    /// [`GradCodec::observe_mean`] after every round).
    widths: Vec<u8>,
    /// Rounds observed so far — drives the [`budget::scheduled_budget`]
    /// ramp.
    round: u64,
    /// Per-bucket second-moment scratch.
    stats: Vec<f64>,
}

/// A [`WireSpec`] instantiated into a working encoder: quantizer + bucket
/// splitter + packing (+ optional parallel pipeline). Owned per node so
/// encoding is lock-free.
pub struct GradCodec {
    method: String,
    packing: Packing,
    quantizer: Box<dyn Quantizer>,
    bucketq: BucketQuantizer,
    is_fp: bool,
    pipeline: Option<BucketPipeline>,
    dscratch: codec::DecodeScratch,
    /// Per-width quantizer bank (`bank[s - 2]` is the s-level instance of
    /// this codec's scheme family), built lazily the first time a width
    /// table is encoded — by the budget path or by a hop matching an
    /// incoming table ([`Self::encode_matched_into`]).
    bank: Vec<Box<dyn Quantizer>>,
    budget: Option<BudgetState>,
    /// Serial width-encode scratch (the parallel path uses the
    /// pipeline's shard arenas instead).
    wqb: crate::quant::QuantizedBucket,
    wclip: Vec<f32>,
    wdeq: Vec<f32>,
}

impl GradCodec {
    pub fn new(spec: &WireSpec) -> Result<GradCodec> {
        let quantizer = quant::from_name(&spec.method)?;
        let is_fp = quantizer.num_levels() == 0;
        let bucketq = match spec.clip_factor {
            Some(c) => BucketQuantizer::with_clip(spec.bucket_size, c),
            None => BucketQuantizer::new(spec.bucket_size),
        };
        let pipeline = spec.build_pipeline();
        Ok(GradCodec {
            method: spec.method.clone(),
            packing: spec.packing,
            quantizer,
            bucketq,
            is_fp,
            pipeline,
            dscratch: codec::DecodeScratch::default(),
            bank: Vec::new(),
            budget: None,
            wqb: crate::quant::QuantizedBucket::default(),
            wclip: Vec::new(),
            wdeq: Vec::new(),
        })
    }

    /// The parameterizable scheme family of `method` (`orq-S`, `qsgd-S`,
    /// `linear-S` → `Some((family, s))`) — the methods whose level count
    /// the budget allocator may vary per bucket.
    fn parse_family(method: &str) -> Option<(&str, usize)> {
        budget::parse_family(method)
    }

    /// Grow the per-width quantizer bank to cover widths `2..=s_max`.
    fn ensure_bank(&mut self, s_max: usize) -> Result<()> {
        let (family, _) = Self::parse_family(&self.method).ok_or_else(|| {
            Error::Config(format!(
                "per-bucket width tables need a parameterizable scheme \
                 (orq-S, qsgd-S or linear-S), got {:?}",
                self.method
            ))
        })?;
        while self.bank.len() + 2 <= s_max {
            let s = self.bank.len() + 2;
            self.bank.push(quant::from_name(&format!("{family}-{s}"))?);
        }
        Ok(())
    }

    /// Arm the adaptive byte budget: every full-gradient encode from this
    /// codec then carries a per-bucket width table chosen by
    /// [`budget::allocate_widths`] so its wire size (headers included)
    /// never exceeds `budget_bytes`. The configured method's level count
    /// caps the per-bucket widths. Errs on `fp` and on the fixed-level
    /// schemes (terngrad, signsgd, bingrad-*) whose width cannot vary.
    pub fn set_budget(
        &mut self,
        budget_bytes: usize,
        schedule: Option<BudgetSchedule>,
    ) -> Result<()> {
        let (_, s_max) = Self::parse_family(&self.method).ok_or_else(|| {
            Error::Config(format!(
                "--byte-budget needs a parameterizable scheme \
                 (orq-S, qsgd-S or linear-S), got {:?}",
                self.method
            ))
        })?;
        self.ensure_bank(s_max)?;
        self.budget = Some(BudgetState {
            budget_bytes,
            schedule,
            s_max,
            widths: Vec::new(),
            round: 0,
            stats: Vec::new(),
        });
        Ok(())
    }

    /// Whether the adaptive byte budget is armed.
    pub fn has_budget(&self) -> bool {
        self.budget.is_some()
    }

    /// Feed the round's decoded mean gradient back into the allocator:
    /// per-bucket second moments of the mean become next round's
    /// statistics, and the width table is recomputed at the next round's
    /// scheduled budget. The mean is bit-identical on every node, so
    /// every node transitions to the identical table. No-op without a
    /// budget.
    pub fn observe_mean(&mut self, mean: &[f32]) {
        let Some(state) = &mut self.budget else { return };
        let d = self.bucketq.bucket_size;
        let nb = mean.len().div_ceil(d.max(1));
        state.stats.clear();
        state.stats.resize(nb, 0.0);
        for (i, &v) in mean.iter().enumerate() {
            let v = if v.is_finite() { v as f64 } else { 0.0 };
            state.stats[i / d] += v * v;
        }
        state.round += 1;
        let b = budget::scheduled_budget(state.budget_bytes, state.schedule, state.round);
        state.widths = budget::allocate_widths(
            &state.stats,
            mean.len(),
            d,
            state.s_max,
            b,
            self.packing,
            &self.method,
        );
    }

    /// The width table in force for the coming round's encode of an
    /// `n`-element gradient, computing the round-0 table (uniform
    /// statistics) on first use. `None` when no budget is armed.
    pub fn round_widths(&mut self, n: usize) -> Option<&[u8]> {
        let Some(state) = &mut self.budget else { return None };
        let nb = n.div_ceil(self.bucketq.bucket_size.max(1));
        if state.widths.len() != nb {
            let b = budget::scheduled_budget(state.budget_bytes, state.schedule, state.round);
            state.widths = budget::allocate_widths(
                &vec![1.0; nb],
                n,
                self.bucketq.bucket_size,
                state.s_max,
                b,
                self.packing,
                &self.method,
            );
        }
        Some(&state.widths)
    }

    /// Whether this codec runs the parallel bucket pipeline.
    pub fn is_parallel(&self) -> bool {
        self.pipeline.is_some()
    }

    pub fn is_fp(&self) -> bool {
        self.is_fp
    }

    pub fn bucket_size(&self) -> usize {
        self.bucketq.bucket_size
    }

    /// Quantize (unless FP or empty) and encode `g` into a reused message
    /// buffer. `qg` is the reusable quantization scratch — steady-state
    /// calls perform no per-bucket allocation.
    ///
    /// Serial codecs (`threads == 1`) advance `rng` through every bucket
    /// in order (the pre-pipeline wire bytes, bit-for-bit). Parallel
    /// codecs draw one round key from `rng` and give each bucket its own
    /// derived stream, so the bytes are identical for every thread count.
    pub fn encode_into(
        &mut self,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) {
        if self.is_fp || g.is_empty() {
            codec::encode_fp_into(g, msg);
            return;
        }
        if self.budget.is_some() {
            // Budgeted full-gradient encode: per-bucket widths in-band.
            let widths = self.take_round_widths(g.len());
            self.encode_widths(&widths, g, rng, msg);
            self.untake_round_widths(widths);
            return;
        }
        self.encode_plain_into(g, rng, qg, msg);
    }

    /// The fixed-width (legacy) encode — bit-identical to the
    /// pre-budget codec regardless of any armed budget. Hops route here
    /// via [`Self::encode_matched_into`]`(None, ..)`.
    fn encode_plain_into(
        &mut self,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) {
        if self.is_fp || g.is_empty() {
            codec::encode_fp_into(g, msg);
            return;
        }
        match &mut self.pipeline {
            None => {
                self.bucketq.quantize_into(g, self.quantizer.as_ref(), rng, qg);
                codec::encode_into(qg, &self.method, self.packing, msg);
            }
            Some(pipe) => {
                let round_key = rng.next_u64();
                pipe.encode_into(
                    &self.bucketq,
                    self.quantizer.as_ref(),
                    g,
                    round_key,
                    &self.method,
                    self.packing,
                    msg,
                );
            }
        }
    }

    /// Move the current round's width table out of the budget state so a
    /// `&mut self` encode can borrow it (restored by
    /// [`Self::untake_round_widths`] — allocation-free swap).
    fn take_round_widths(&mut self, n: usize) -> Vec<u8> {
        self.round_widths(n);
        self.budget.as_mut().map(|s| std::mem::take(&mut s.widths)).unwrap_or_default()
    }

    fn untake_round_widths(&mut self, widths: Vec<u8>) {
        if let Some(state) = &mut self.budget {
            state.widths = widths;
        }
    }

    /// Width-table encode core. Both the budget path and the matched-hop
    /// path land here: one round key from `rng` with per-bucket derived
    /// streams (the pipeline discipline) in *both* execution modes, so
    /// budgeted wire bytes are invariant across thread counts — serial
    /// budgeted runs intentionally trade the legacy advancing-stream
    /// bytes for that invariance (without a budget nothing changes).
    fn encode_widths(&mut self, widths: &[u8], g: &[f32], rng: &mut Rng, msg: &mut Vec<u8>) {
        debug_assert!(!g.is_empty(), "width tables describe at least one bucket");
        let round_key = rng.next_u64();
        match &mut self.pipeline {
            Some(pipe) => pipe.encode_widths_into(
                &self.bucketq,
                &self.bank,
                widths,
                g,
                round_key,
                &self.method,
                self.packing,
                msg,
            ),
            None => {
                msg.clear();
                codec::encode_quantized_header_widths_into(
                    widths,
                    &self.method,
                    self.packing,
                    g.len(),
                    self.bucketq.bucket_size,
                    msg,
                );
                let d = self.bucketq.bucket_size;
                for (bi, &w) in widths.iter().enumerate() {
                    let lo = bi * d;
                    let hi = (lo + d).min(g.len());
                    let q = self.bank[w as usize - 2].as_ref();
                    self.bucketq.quantize_bucket_stream(
                        &g[lo..hi],
                        bi,
                        q,
                        round_key,
                        &mut self.wclip,
                        &mut self.wqb,
                    );
                    codec::BucketEncoder::new(w as usize, self.packing)
                        .encode_bucket_into(&self.wqb, msg);
                }
            }
        }
    }

    /// Encode `g` at the widths of a *received* message: `Some(table)`
    /// re-encodes each bucket at the table's width (the hop sites of the
    /// ring and hierarchy, which must requantize at the widths they
    /// decoded — [`codec::capture_widths`] — never at widths they would
    /// derive themselves); `None` is exactly the legacy fixed-width
    /// encode. Errs if the table length does not match `g`'s bucket grid
    /// or the scheme cannot vary its level count.
    pub fn encode_matched_into(
        &mut self,
        widths: Option<&[u8]>,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) -> Result<()> {
        let Some(table) = widths else {
            self.encode_plain_into(g, rng, qg, msg);
            return Ok(());
        };
        let nb = g.len().div_ceil(self.bucketq.bucket_size.max(1));
        if table.len() != nb || nb == 0 {
            return Err(Error::Comm(format!(
                "width table has {} entries but the gradient has {nb} buckets",
                table.len()
            )));
        }
        let s_max = table.iter().copied().max().unwrap_or(2).max(2) as usize;
        self.ensure_bank(s_max)?;
        self.encode_widths(table, g, rng, msg);
        Ok(())
    }

    /// Error-feedback twin of [`Self::encode_matched_into`].
    pub fn encode_matched_ef_into(
        &mut self,
        widths: Option<&[u8]>,
        ef: &mut ErrorFeedback,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) -> Result<()> {
        let Some(table) = widths else {
            self.encode_plain_ef_into(ef, g, rng, qg, msg);
            return Ok(());
        };
        let nb = g.len().div_ceil(self.bucketq.bucket_size.max(1));
        if table.len() != nb || nb == 0 {
            return Err(Error::Comm(format!(
                "width table has {} entries but the gradient has {nb} buckets",
                table.len()
            )));
        }
        let s_max = table.iter().copied().max().unwrap_or(2).max(2) as usize;
        self.ensure_bank(s_max)?;
        self.encode_widths_ef(table, ef, g, rng, msg);
        Ok(())
    }

    /// Width-table error-feedback core: quantize the compensated signal
    /// `g + m` at the given widths, recover the residual through the
    /// width-aware decode of the message just written.
    fn encode_widths_ef(
        &mut self,
        widths: &[u8],
        ef: &mut ErrorFeedback,
        g: &[f32],
        rng: &mut Rng,
        msg: &mut Vec<u8>,
    ) {
        if let Some(pipe) = &mut self.pipeline {
            let round_key = rng.next_u64();
            pipe.encode_widths_ef_into(
                &self.bucketq,
                &self.bank,
                widths,
                ef,
                g,
                round_key,
                &self.method,
                self.packing,
                msg,
            );
            return;
        }
        {
            // `comp` borrows `ef`, which is disjoint from `self`.
            let comp = ef.compensate(g);
            self.encode_widths(widths, comp, rng, msg);
        }
        let mut deq = std::mem::take(&mut self.wdeq);
        codec::decode_flat_into(msg, &mut deq, &mut self.dscratch)
            .expect("own encoding always decodes");
        ef.update_residual(&deq);
        self.wdeq = deq;
    }

    /// Build error-feedback state matching this codec's bucket/clip
    /// configuration. Works for serial and parallel codecs alike: the
    /// serial path updates the residual from the materialized
    /// [`QuantizedGrad`], the parallel path through the pipeline-side
    /// dequantization buffer
    /// ([`BucketPipeline::encode_ef_into`]).
    pub fn error_feedback(&self) -> ErrorFeedback {
        ErrorFeedback::new(self.bucketq.clone())
    }

    /// The error-feedback twin of [`Self::encode_into`]: quantize
    /// `g + m` through `ef` (residual memory updated in place) and
    /// encode with this codec's scheme and packing. Serial codecs keep
    /// the PR 4 path bit-for-bit; parallel codecs shard the compensated
    /// signal like any other gradient (wire bytes identical for every
    /// thread count) and recover the residual by decoding their own
    /// message.
    pub fn encode_ef_into(
        &mut self,
        ef: &mut ErrorFeedback,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) {
        if self.budget.is_some() && !g.is_empty() {
            let widths = self.take_round_widths(g.len());
            self.encode_widths_ef(&widths, ef, g, rng, msg);
            self.untake_round_widths(widths);
            return;
        }
        self.encode_plain_ef_into(ef, g, rng, qg, msg);
    }

    /// The fixed-width (legacy) error-feedback encode.
    fn encode_plain_ef_into(
        &mut self,
        ef: &mut ErrorFeedback,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) {
        debug_assert!(
            !self.is_fp,
            "EF needs a quantizing method (config validation enforces this)"
        );
        match &mut self.pipeline {
            None => {
                ef.quantize_into(g, self.quantizer.as_ref(), rng, qg);
                codec::encode_into(qg, &self.method, self.packing, msg);
            }
            Some(pipe) => {
                let round_key = rng.next_u64();
                pipe.encode_ef_into(
                    &self.bucketq,
                    self.quantizer.as_ref(),
                    ef,
                    g,
                    round_key,
                    &self.method,
                    self.packing,
                    msg,
                );
            }
        }
    }

    /// The dequantized transmitted signal of the last parallel
    /// [`Self::encode_ef_into`] call (the buffer the pipeline's residual
    /// update decoded); `None` on serial codecs, which materialize the
    /// [`QuantizedGrad`] instead. Lets the trainer measure quantization
    /// error without decoding the same message twice.
    pub fn ef_dequant(&self) -> Option<&[f32]> {
        match &self.pipeline {
            Some(p) => Some(p.ef_dequant()),
            // Serial budgeted EF also recovers the residual through the
            // wire decode (no QuantizedGrad is materialized).
            None if self.budget.is_some() => Some(&self.wdeq),
            None => None,
        }
    }

    /// Decode a wire message into a flat f32 buffer, using the parallel
    /// pipeline when this codec has one (serial otherwise). The trainer's
    /// per-step error measurement uses this on the parallel path, where
    /// no [`QuantizedGrad`] is materialized.
    pub fn decode_flat_into(&mut self, bytes: &[u8], out: &mut Vec<f32>) -> Result<()> {
        match &mut self.pipeline {
            Some(pipe) => pipe.decode_flat_into(bytes, out),
            None => codec::decode_flat_into(bytes, out, &mut self.dscratch),
        }
    }
}

/// One worker's per-round transmission trace on a topology's global
/// synchronous step grid: `step_bytes[k]` is the bytes that worker sent
/// in step `k` (0 = silent that step). Shared by the ring and
/// hierarchical coordinators' critical-path accounting. Streamed rounds
/// additionally carry one `(ready_s, frame_bytes)` row per pushed
/// section frame, in send order — the coordinator replays the pipeline
/// recurrence `start = max(ready, link_free)` from these rows (workers
/// with no wire leg that round report `frame_bytes = 0`).
pub(crate) struct RoundTrace {
    pub(crate) worker: usize,
    pub(crate) step_bytes: Vec<usize>,
    pub(crate) stream: Vec<(f64, usize)>,
    /// The worker's flat encoded message size this round (0 on streamed
    /// rounds) — what the closed-form `allreduce_time`/`hier_time`
    /// models take as the message size for drift accounting.
    pub(crate) msg_bytes: usize,
}

/// Collect exactly one trace from each of `l` workers — `steps`
/// step-grid slots and `stream_rows` streamed-frame rows (0 on flat
/// rounds) — validating worker ids, duplicates, and record lengths;
/// returns the traces indexed by worker id. `what` names the topology
/// in errors.
pub(crate) fn collect_traces(
    rx: &Receiver<RoundTrace>,
    l: usize,
    steps: usize,
    stream_rows: usize,
    what: &str,
) -> Result<Vec<RoundTrace>> {
    let mut traces: Vec<Option<RoundTrace>> = (0..l).map(|_| None).collect();
    for _ in 0..l {
        let t = rx
            .recv()
            .map_err(|_| Error::Comm(format!("{what} worker died mid-round")))?;
        if t.worker >= l {
            return Err(Error::Comm(format!("unknown {what} worker {}", t.worker)));
        }
        if traces[t.worker].is_some() {
            return Err(Error::Comm(format!(
                "duplicate trace from {what} worker {}",
                t.worker
            )));
        }
        if t.step_bytes.len() != steps {
            return Err(Error::Comm(format!(
                "{what} worker {} sent {} step records, expected {steps}",
                t.worker,
                t.step_bytes.len()
            )));
        }
        if t.stream.len() != stream_rows {
            return Err(Error::Comm(format!(
                "{what} worker {} sent {} stream rows, expected {stream_rows}",
                t.worker,
                t.stream.len()
            )));
        }
        traces[t.worker] = Some(t);
    }
    Ok(traces.into_iter().map(|t| t.expect("all slots filled")).collect())
}

/// Coordinator end of a topology (lives on the trainer's main thread).
pub trait Collective: Send {
    fn num_workers(&self) -> usize;

    /// Serve one synchronous exchange round and write the round's decoded
    /// mean gradient — bit-identical to what every worker's
    /// [`WorkerExchange::exchange`] returned — into `mean_out`.
    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()>;

    /// Cumulative totals since construction. Per-round figures are deltas
    /// between consecutive calls.
    fn stats(&self) -> CommStats;

    /// Exact wire bytes through each server shard, for topologies that
    /// shard their aggregation ([`Topology::ShardedPs`]); `None`
    /// elsewhere.
    fn shard_bytes(&self) -> Option<Vec<u64>> {
        None
    }
}

/// Worker end of a topology (one per worker thread).
pub trait WorkerExchange: Send {
    fn id(&self) -> usize;

    /// Contribute this round's encoded gradient (the implementation may
    /// take the buffer), block for the exchange, and write the decoded
    /// mean gradient into `mean_out`.
    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()>;

    /// Streamed rounds: contribute one section's standalone encoded
    /// message (`payload`) the moment it is staged — strict descending
    /// section order, with the section's deterministic readiness stamp
    /// in simulated seconds since the round's backward began. The frame
    /// hits the wire immediately; after the last section,
    /// [`Self::finish_streamed`] completes the round. Only topologies
    /// built with [`ExchangeConfig::with_streaming`] accept the call.
    fn push_section(&mut self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let _ = (payload, ready_s);
        Err(Error::InvalidArg(format!(
            "this exchange was not built for streaming (section {section} refused); \
             construct the topology with ExchangeConfig::with_streaming"
        )))
    }

    /// Complete a streamed round once every section was pushed: block
    /// for the exchange and write the round's decoded mean gradient.
    /// Pairs with [`Self::push_section`].
    fn finish_streamed(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let _ = mean_out;
        Err(Error::InvalidArg(
            "this exchange was not built for streaming \
             (construct the topology with ExchangeConfig::with_streaming)"
                .into(),
        ))
    }
}

/// The two ends of a built topology: the coordinator and one worker end
/// per worker thread.
pub type TopologyEnds = (Box<dyn Collective>, Vec<Box<dyn WorkerExchange>>);

/// Construct a topology's two ends.
pub fn build_topology(
    cfg: &ExchangeConfig,
    workers: usize,
    spec: &WireSpec,
) -> Result<TopologyEnds> {
    cfg.validate(workers)?;
    let streamed = cfg.streamed_sections();
    match cfg.topology {
        Topology::Ps => {
            let (coord, ends) = PsCollective::new(
                workers,
                cfg.links,
                spec,
                cfg.quantize_downlink,
                cfg.error_feedback,
                streamed,
            )?;
            Ok((
                Box::new(coord),
                ends.into_iter().map(|e| Box::new(e) as Box<dyn WorkerExchange>).collect(),
            ))
        }
        Topology::Ring => {
            let (coord, ends) =
                RingAllReduce::new(workers, cfg.links, spec, cfg.error_feedback, streamed)?;
            Ok((
                Box::new(coord),
                ends.into_iter().map(|e| Box::new(e) as Box<dyn WorkerExchange>).collect(),
            ))
        }
        Topology::Hier => {
            let (coord, ends) = HierarchicalCollective::new(
                workers,
                cfg.groups,
                cfg.links,
                spec,
                cfg.quantize_downlink,
                cfg.error_feedback,
                streamed,
            )?;
            Ok((
                Box::new(coord),
                ends.into_iter().map(|e| Box::new(e) as Box<dyn WorkerExchange>).collect(),
            ))
        }
        Topology::ShardedPs => {
            let (coord, ends) = ShardedPsCollective::new(
                workers,
                cfg.shards,
                cfg.staleness,
                cfg.links,
                spec,
                cfg.quantize_downlink,
                cfg.error_feedback,
                streamed,
            )?;
            Ok((
                Box::new(coord),
                ends.into_iter().map(|e| Box::new(e) as Box<dyn WorkerExchange>).collect(),
            ))
        }
    }
}

/// One worker's multi-round drive loop (shared by the pooled and scoped
/// drivers of [`run_rounds`]). With `error_feedback` on (and a lossy
/// codec), the uplink is compensated across rounds exactly like the
/// trainer's worker loop.
fn drive_worker(
    spec: &WireSpec,
    error_feedback: bool,
    w: usize,
    g: &[f32],
    mut wx: Box<dyn WorkerExchange>,
    rounds: usize,
) {
    let mut gc = GradCodec::new(spec).expect("spec validated by build_topology");
    let mut ef = (error_feedback && !gc.is_fp()).then(|| gc.error_feedback());
    let mut rng = Rng::stream(spec.seed, 2_000 + w as u64);
    let mut qg = QuantizedGrad::default();
    let mut msg = Vec::new();
    let mut mean = Vec::new();
    for _ in 0..rounds {
        match &mut ef {
            Some(ef) => gc.encode_ef_into(ef, g, &mut rng, &mut qg, &mut msg),
            None => gc.encode_into(g, &mut rng, &mut qg, &mut msg),
        }
        // On channel death the coordinator's round() surfaces the real
        // error; a panic here would only mask it.
        if wx.exchange(&mut msg, &mut mean).is_err() {
            return;
        }
    }
}

/// The streamed counterpart of [`drive_worker`]: run the section-streamed
/// overlap encoder over a synthetic equal-span layer structure
/// (`sections` single-layer sections, readiness stamps from
/// [`SectionMap::ready_schedule`] at [`SIM_BACKWARD_RATE`]) and push each
/// section frame as its encode completes, then finish the round. Uplink
/// EF settles exactly like the trainer's overlap loop: the residual is
/// staged section-wise into the encode and updated from the assembled
/// flat message afterwards, so the wire bytes (and the broadcast-topology
/// means) match the flat parallel EF path bit for bit.
fn drive_worker_streamed(
    spec: &WireSpec,
    error_feedback: bool,
    sections: usize,
    w: usize,
    g: &[f32],
    mut wx: Box<dyn WorkerExchange>,
    rounds: usize,
) {
    let n = g.len();
    let spans: Vec<std::ops::Range<usize>> =
        (0..sections).map(|i| n * i / sections..n * (i + 1) / sections).collect();
    // Construction errors close this worker's channels; the
    // coordinator's round() surfaces the failure (run_rounds_streamed
    // pre-validates the same construction on the driver thread).
    let Ok(map) = SectionMap::new(&spans, sections, spec.bucket_size) else {
        return;
    };
    let Ok(mut ov) = OverlapEncoder::new(spec, map) else {
        return;
    };
    let Ok(mut gc) = GradCodec::new(spec) else {
        return;
    };
    let ready = ov.map().ready_schedule(SIM_BACKWARD_RATE);
    let mut ef = error_feedback.then(|| gc.error_feedback());
    let mut rng = Rng::stream(spec.seed, 2_000 + w as u64);
    let mut msg = Vec::new();
    let mut deq = Vec::new();
    let mut mean = Vec::new();
    for _ in 0..rounds {
        let memory = ef.as_mut().map(|e| e.residual(n));
        let res = ov.encode_streamed(
            memory,
            &mut rng,
            &mut msg,
            &ready,
            &mut |sec, payload, r| wx.push_section(sec, payload, r),
            |cb| {
                // Synthetic reverse-layer backward: frontiers descend to 0.
                for s in spans.iter().rev() {
                    cb(s.start, g);
                }
                0.0
            },
        );
        if res.is_err() || wx.finish_streamed(&mut mean).is_err() {
            return;
        }
        if let Some(ef) = &mut ef {
            // m ← (g + m) − deq(own assembled message), the trainer's
            // post-overlap settle.
            if gc.decode_flat_into(&msg, &mut deq).is_err() {
                return;
            }
            ef.compensate(g);
            ef.update_residual(&deq);
        }
    }
}

/// The coordinator half of [`run_rounds`], shared by the pooled and
/// scoped drivers: serve every round, then report cumulative stats.
/// The caller must still drop the collective before its scope
/// joins/drains (the drop-before-join teardown convention) so that on a
/// mid-exchange error, workers blocked on its channels see them close
/// and exit instead of deadlocking.
fn drive_coordinator(
    coll: &mut dyn Collective,
    mean: &mut Vec<f32>,
    rounds: usize,
) -> Result<CommStats> {
    let mut round_res = Ok(());
    for _ in 0..rounds {
        if let Err(e) = coll.round(mean) {
            round_res = Err(e);
            break;
        }
    }
    let stats = coll.stats();
    round_res.map(|()| stats)
}

/// Drive `rounds` exchange rounds over one built topology: each worker
/// re-encodes the same gradient every round (the spec's quantizer RNG
/// streams advance across rounds) and exchanges; returns the last
/// round's decoded mean and the cumulative stats. Asynchronous sharded
/// topologies pipeline inside their staleness window, so multi-round
/// drives are what exercise (and measure) warm rounds. `rounds == 0`
/// moves nothing and returns an empty mean.
///
/// Worker loops run on the spec's [`PoolMode`]: the default `Pooled` is
/// upgraded to one run-local [`PoolMode::Shared`] pool so every codec
/// and shard reduce loop of this drive reuses the same threads across
/// all rounds (callers that pass `Shared` themselves amortize across
/// *calls* too — what perfbench measures); `Scoped` keeps the PR 4
/// scoped-thread driver as the baseline. This is the one copy of the
/// drop-before-join teardown convention benches and tests should reuse.
pub fn run_rounds(
    cfg: &ExchangeConfig,
    spec: &WireSpec,
    grads: &[Vec<f32>],
    rounds: usize,
) -> Result<(Vec<f32>, CommStats)> {
    if cfg.streaming {
        return Err(Error::InvalidArg(
            "run_rounds drives the flat exchange; use run_rounds_streamed for a \
             streaming ExchangeConfig"
                .into(),
        ));
    }
    run_rounds_impl(cfg, spec, grads, rounds, false)
}

/// The streamed twin of [`run_rounds`]: every worker runs the
/// section-streamed overlap encoder ([`OverlapEncoder::encode_streamed`])
/// over a synthetic equal-span layer structure with `cfg.sections`
/// sections and pushes section frames into the collective as backward
/// "produces" them, then completes the round with
/// [`WorkerExchange::finish_streamed`]. Requires a streaming
/// [`ExchangeConfig`] ([`ExchangeConfig::with_streaming`]) and a
/// quantizing method (FP has no bucket grid to stream).
///
/// Timing contract: a streamed round's `sim_time_s` delta is measured
/// from the round's *backward start* — it includes the wait for each
/// section's readiness stamp, because that wait is exactly what the
/// streaming overlap hides comm behind. Compare against
/// `ready_last + flat round time`, not the flat round time alone (the
/// flat exchange can only start once backward has finished).
pub fn run_rounds_streamed(
    cfg: &ExchangeConfig,
    spec: &WireSpec,
    grads: &[Vec<f32>],
    rounds: usize,
) -> Result<(Vec<f32>, CommStats)> {
    if !cfg.streaming {
        return Err(Error::InvalidArg(
            "run_rounds_streamed needs a streaming ExchangeConfig \
             (ExchangeConfig::with_streaming)"
                .into(),
        ));
    }
    // Surface worker-side construction errors (FP method, bucket
    // mismatch, bad section count) on the driver thread, where they can
    // carry their real message.
    let n = grads.first().map_or(0, |g| g.len());
    let spans: Vec<std::ops::Range<usize>> = (0..cfg.sections)
        .map(|i| n * i / cfg.sections..n * (i + 1) / cfg.sections)
        .collect();
    OverlapEncoder::new(spec, SectionMap::new(&spans, cfg.sections, spec.bucket_size)?)?;
    run_rounds_impl(cfg, spec, grads, rounds, true)
}

fn run_rounds_impl(
    cfg: &ExchangeConfig,
    spec: &WireSpec,
    grads: &[Vec<f32>],
    rounds: usize,
    streamed: bool,
) -> Result<(Vec<f32>, CommStats)> {
    let spec = match &spec.pool {
        PoolMode::Pooled => {
            spec.clone().with_pool_mode(PoolMode::Shared(PoolHandle::new(spec.threads)))
        }
        _ => spec.clone(),
    };
    let (mut coll, ends) = build_topology(cfg, grads.len(), &spec)?;
    let sections = cfg.sections;
    let mut mean = Vec::new();
    let shared = spec.pool.shared().cloned();
    let stats = match shared {
        Some(pool) => {
            let spec = &spec;
            let ef = cfg.error_feedback;
            let coordinated: Result<Result<CommStats>> = pool.scope(|sc| {
                for (w, wx) in ends.into_iter().enumerate() {
                    let g: &[f32] = &grads[w];
                    sc.spawn(move || {
                        if streamed {
                            drive_worker_streamed(spec, ef, sections, w, g, wx, rounds)
                        } else {
                            drive_worker(spec, ef, w, g, wx, rounds)
                        }
                    });
                }
                let res = drive_coordinator(coll.as_mut(), &mut mean, rounds);
                // Tear the coordinator down before the scope drains (see
                // drive_coordinator's teardown note).
                drop(coll);
                res
            });
            coordinated??
        }
        None => {
            let res: Result<CommStats> = std::thread::scope(|scope| {
                for (w, wx) in ends.into_iter().enumerate() {
                    let g: &[f32] = &grads[w];
                    let spec = &spec;
                    scope.spawn(move || {
                        if streamed {
                            drive_worker_streamed(spec, cfg.error_feedback, sections, w, g, wx, rounds)
                        } else {
                            drive_worker(spec, cfg.error_feedback, w, g, wx, rounds)
                        }
                    });
                }
                let res = drive_coordinator(coll.as_mut(), &mut mean, rounds);
                // Same drop-before-join convention as the pooled driver.
                drop(coll);
                res
            });
            res?
        }
    };
    Ok((mean, stats))
}

/// Drive one full exchange round over `grads` (one per worker): the
/// `rounds == 1` case of [`run_rounds`]. Used by the Table 1 bench
/// ("measured" columns) and the topology-equivalence tests.
pub fn run_once(
    cfg: &ExchangeConfig,
    spec: &WireSpec,
    grads: &[Vec<f32>],
) -> Result<(Vec<f32>, CommStats)> {
    run_rounds(cfg, spec, grads, 1)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_parse_roundtrip() {
        assert_eq!(Topology::parse("ps").unwrap(), Topology::Ps);
        assert_eq!(Topology::parse("star").unwrap(), Topology::Ps);
        assert_eq!(Topology::parse("ring").unwrap(), Topology::Ring);
        assert_eq!(Topology::parse("hier").unwrap(), Topology::Hier);
        assert_eq!(Topology::parse("hierarchical").unwrap(), Topology::Hier);
        assert_eq!(Topology::parse("sharded-ps").unwrap(), Topology::ShardedPs);
        assert_eq!(Topology::parse("sharded").unwrap(), Topology::ShardedPs);
        assert!(Topology::parse("mesh").is_err());
        assert_eq!(Topology::Ring.to_string(), "ring");
        assert_eq!(Topology::Hier.to_string(), "hier");
        assert_eq!(Topology::ShardedPs.to_string(), "sharded-ps");
        assert_eq!("ps".parse::<Topology>().unwrap(), Topology::Ps);
        assert_eq!("sharded-ps".parse::<Topology>().unwrap(), Topology::ShardedPs);
        assert_eq!(Topology::default(), Topology::Ps);
    }

    #[test]
    fn exchange_config_validation() {
        let link = Link::ten_gbps();
        // flat topologies reject groups != 1
        let mut c = ExchangeConfig::flat(Topology::Ps, link);
        c.groups = 2;
        assert!(c.validate(4).is_err());
        let mut c = ExchangeConfig::flat(Topology::Ring, link);
        c.groups = 2;
        assert!(c.validate(4).is_err());
        // hier needs a positive divisor of the worker count
        assert!(ExchangeConfig::hier(3, LinkMap::uniform(link)).validate(4).is_err());
        assert!(ExchangeConfig::hier(0, LinkMap::uniform(link)).validate(4).is_err());
        assert!(ExchangeConfig::hier(2, LinkMap::uniform(link)).validate(4).is_ok());
        // downlink quantization applies to every broadcast topology; only
        // the ring (no broadcast downlink) rejects it
        assert!(ExchangeConfig::flat(Topology::Ps, link).with_downlink(true).validate(2).is_ok());
        assert!(ExchangeConfig::flat(Topology::Ring, link)
            .with_downlink(true)
            .validate(2)
            .is_err());
        assert!(ExchangeConfig::hier(2, LinkMap::uniform(link))
            .with_downlink(true)
            .validate(2)
            .is_ok());
        assert!(ExchangeConfig::sharded(2, 0, link).with_downlink(true).validate(2).is_ok());
        // per-hop error feedback is a pure transport option everywhere
        assert!(ExchangeConfig::flat(Topology::Ring, link)
            .with_error_feedback(true)
            .validate(2)
            .is_ok());
        assert!(ExchangeConfig::hier(2, LinkMap::uniform(link))
            .with_error_feedback(true)
            .with_downlink(true)
            .validate(4)
            .is_ok());
        // sharding and staleness are sharded-ps-only knobs
        assert!(ExchangeConfig::sharded(2, 3, link).validate(4).is_ok());
        assert!(ExchangeConfig::sharded(0, 0, link).validate(4).is_err());
        let mut c = ExchangeConfig::flat(Topology::Ps, link);
        c.shards = 2;
        assert!(c.validate(4).is_err());
        let mut c = ExchangeConfig::flat(Topology::Ring, link);
        c.staleness = 1;
        assert!(c.validate(4).is_err());
        let mut c = ExchangeConfig::hier(2, LinkMap::uniform(link));
        c.staleness = 1;
        assert!(c.validate(4).is_err());
        let mut c = ExchangeConfig::sharded(2, 0, link);
        c.groups = 2;
        assert!(c.validate(4).is_err());
    }

    #[test]
    fn grad_codec_fp_and_quantized() {
        let g: Vec<f32> = (0..300).map(|i| (i as f32 - 150.0) / 50.0).collect();
        let mut rng = Rng::seed_from(1);
        let mut qg = QuantizedGrad::default();
        let mut msg = Vec::new();

        let mut fp = GradCodec::new(&WireSpec::new("fp", 128)).unwrap();
        assert!(fp.is_fp());
        fp.encode_into(&g, &mut rng, &mut qg, &mut msg);
        assert_eq!(msg, codec::encode_fp(&g));

        let mut tg = GradCodec::new(&WireSpec::new("terngrad", 128)).unwrap();
        assert!(!tg.is_fp());
        assert_eq!(tg.bucket_size(), 128);
        tg.encode_into(&g, &mut rng, &mut qg, &mut msg);
        assert_eq!(
            msg.len(),
            codec::wire_size(300, 128, 3, Packing::BaseS, "terngrad")
        );
        // empty gradients fall back to the FP framing (a quantized message
        // cannot represent s levels with zero buckets)
        tg.encode_into(&[], &mut rng, &mut qg, &mut msg);
        assert!(codec::decode(&msg).unwrap().is_empty());

        assert!(GradCodec::new(&WireSpec::new("bogus", 128)).is_err());
    }

    /// Parallel codecs must emit identical wire bytes for every thread
    /// count (per-bucket RNG streams), and the default `threads == 1`
    /// codec must keep the legacy single-stream bytes.
    #[test]
    fn grad_codec_threads_bit_identity() {
        let g: Vec<f32> = {
            let mut rng = Rng::seed_from(9);
            (0..2500).map(|_| rng.gaussian_f32()).collect()
        };
        let mut qg = QuantizedGrad::default();
        // legacy serial path: same bytes as quantize_into + encode
        let mut serial = GradCodec::new(&WireSpec::new("orq-5", 256)).unwrap();
        let mut legacy = Vec::new();
        serial.encode_into(&g, &mut Rng::seed_from(4), &mut qg, &mut legacy);
        let q = quant::from_name("orq-5").unwrap();
        let mut want = QuantizedGrad::default();
        BucketQuantizer::new(256).quantize_into(&g, q.as_ref(), &mut Rng::seed_from(4), &mut want);
        assert_eq!(legacy, codec::encode(&want, "orq-5", Packing::BaseS));
        // parallel path: thread-count independent
        let mut reference: Option<Vec<u8>> = None;
        for threads in [2usize, 3, 8] {
            let spec = WireSpec::new("orq-5", 256).with_threads(threads);
            let mut gc = GradCodec::new(&spec).unwrap();
            let mut msg = Vec::new();
            gc.encode_into(&g, &mut Rng::seed_from(4), &mut qg, &mut msg);
            match &reference {
                None => reference = Some(msg.clone()),
                Some(r) => assert_eq!(&msg, r, "threads={threads}"),
            }
        }
    }

    /// The decay regression of `quant::error_feedback`, extended to the
    /// pooled parallel codec: feeding the same gradient repeatedly, the
    /// cumulative transmitted mean must converge on the true gradient
    /// (relative error decaying between checkpoints), which the plain
    /// biased quantizer cannot do. Exercises the pipeline-side residual
    /// across many rounds on one persistent pool.
    #[test]
    fn pooled_parallel_error_feedback_decays_across_rounds() {
        let g: Vec<f32> = {
            let mut rng = Rng::seed_from(21);
            (0..768).map(|_| rng.gaussian_f32()).collect()
        };
        let norm2 = |v: &[f32]| v.iter().map(|x| (*x as f64).powi(2)).sum::<f64>().sqrt();
        let spec = WireSpec::new("bingrad-b", 256).with_threads(3);
        let mut gc = GradCodec::new(&spec).unwrap();
        assert!(gc.is_parallel());
        let mut ef = gc.error_feedback();
        let mut rng = Rng::seed_from(22);
        let mut qg = QuantizedGrad::default();
        let mut msg = Vec::new();
        let mut deq = Vec::new();
        let mut sum = vec![0.0f32; g.len()];
        let err_at = |sum: &[f32], t: usize| {
            let diff: Vec<f32> =
                sum.iter().zip(&g).map(|(s, gi)| s / t as f32 - gi).collect();
            norm2(&diff) / norm2(&g)
        };
        let mut checkpoints = Vec::new();
        for t in 1..=32usize {
            gc.encode_ef_into(&mut ef, &g, &mut rng, &mut qg, &mut msg);
            gc.decode_flat_into(&msg, &mut deq).unwrap();
            for (s, v) in sum.iter_mut().zip(&deq) {
                *s += v;
            }
            if t == 1 || t == 8 || t == 32 {
                checkpoints.push(err_at(&sum, t));
            }
        }
        assert!(
            checkpoints[1] < 0.6 * checkpoints[0],
            "relative error must decay under pooled EF: {checkpoints:?}"
        );
        assert!(
            checkpoints[2] < 0.6 * checkpoints[1],
            "…and keep decaying: {checkpoints:?}"
        );
    }

    /// One spec, three execution modes (pooled, shared pool, scoped):
    /// the wire bytes must be bit-identical — the pool is pure execution.
    #[test]
    fn grad_codec_pool_modes_bit_identical() {
        let g: Vec<f32> = {
            let mut rng = Rng::seed_from(13);
            (0..3000).map(|_| rng.gaussian_f32()).collect()
        };
        let mut qg = QuantizedGrad::default();
        let mut reference: Option<Vec<Vec<u8>>> = None;
        let handle = PoolHandle::new(2);
        for mode in [
            PoolMode::Pooled,
            PoolMode::Shared(handle.clone()),
            PoolMode::Scoped,
        ] {
            let spec = WireSpec::new("linear-9", 256).with_threads(4).with_pool_mode(mode);
            let mut gc = GradCodec::new(&spec).unwrap();
            let mut msg = Vec::new();
            // several rounds so arenas are reused in the pooled modes
            let rounds_bytes: Vec<Vec<u8>> = (0..3u64)
                .map(|round| {
                    gc.encode_into(&g, &mut Rng::seed_from(round), &mut qg, &mut msg);
                    msg.clone()
                })
                .collect();
            match &reference {
                None => reference = Some(rounds_bytes),
                Some(want) => assert_eq!(&rounds_bytes, want, "{:?}", spec.pool),
            }
        }
    }

    /// `encode_ef_into` must be byte-identical to running the standalone
    /// `ErrorFeedback` over the same bucket config and encoding the
    /// result — one wire format, whether compensated or not.
    #[test]
    fn grad_codec_error_feedback_matches_manual_path() {
        let g: Vec<f32> = (0..600).map(|i| (i as f32 - 300.0) / 90.0).collect();
        let mut gc = GradCodec::new(&WireSpec::new("bingrad-b", 128)).unwrap();
        let mut ef = gc.error_feedback();
        let mut qg = QuantizedGrad::default();
        let mut msg = Vec::new();
        gc.encode_ef_into(&mut ef, &g, &mut Rng::seed_from(5), &mut qg, &mut msg);
        let q = quant::from_name("bingrad-b").unwrap();
        let mut ef2 = ErrorFeedback::new(BucketQuantizer::new(128));
        let mut qg2 = QuantizedGrad::default();
        ef2.quantize_into(&g, q.as_ref(), &mut Rng::seed_from(5), &mut qg2);
        assert_eq!(msg, codec::encode(&qg2, "bingrad-b", Packing::BaseS));
        // a second round compensates: the transmitted signal differs from
        // the plain (memoryless) quantization of the same gradient
        gc.encode_ef_into(&mut ef, &g, &mut Rng::seed_from(6), &mut qg, &mut msg);
        let mut plain = GradCodec::new(&WireSpec::new("bingrad-b", 128)).unwrap();
        let mut msg2 = Vec::new();
        plain.encode_into(&g, &mut Rng::seed_from(6), &mut qg2, &mut msg2);
        assert_ne!(msg, msg2, "round 2 must carry the residual");
    }

    #[test]
    fn build_topology_rejects_bad_method() {
        let spec = WireSpec::new("not-a-method", 64);
        let link = Link::ten_gbps();
        assert!(build_topology(&ExchangeConfig::flat(Topology::Ps, link), 2, &spec).is_err());
        assert!(build_topology(&ExchangeConfig::flat(Topology::Ring, link), 2, &spec).is_err());
        let hier = ExchangeConfig::hier(2, LinkMap::uniform(link));
        assert!(build_topology(&hier, 2, &spec).is_err());
        assert!(build_topology(&ExchangeConfig::sharded(2, 0, link), 2, &spec).is_err());
    }

    #[test]
    fn only_the_ring_rejects_downlink_quantization() {
        let spec = WireSpec::new("terngrad", 64);
        let link = Link::ten_gbps();
        let ring_q = ExchangeConfig::flat(Topology::Ring, link).with_downlink(true);
        assert!(build_topology(&ring_q, 2, &spec).is_err());
        let ring = ExchangeConfig::flat(Topology::Ring, link);
        assert!(build_topology(&ring, 2, &spec).is_ok());
        let ps_q = ExchangeConfig::flat(Topology::Ps, link).with_downlink(true);
        assert!(build_topology(&ps_q, 2, &spec).is_ok());
        let hier_q = ExchangeConfig::hier(2, LinkMap::uniform(link)).with_downlink(true);
        assert!(build_topology(&hier_q, 4, &spec).is_ok());
        let sharded_q = ExchangeConfig::sharded(2, 0, link).with_downlink(true);
        assert!(build_topology(&sharded_q, 2, &spec).is_ok());
    }

    /// A coordinator-side error (mismatched upload shapes) must surface as
    /// Err, not deadlock the scoped join (regression: workers used to stay
    /// blocked on the still-open broadcast channels).
    #[test]
    fn run_once_surfaces_shape_errors_instead_of_hanging() {
        let spec = WireSpec::new("fp", 64);
        let grads = vec![vec![0.5f32; 128], vec![0.5f32; 256]];
        let err = run_once(&ExchangeConfig::flat(Topology::Ps, Link::ten_gbps()), &spec, &grads);
        assert!(err.is_err(), "mismatched gradient lengths must error");
    }

    /// Same property for the hierarchy: a mismatched contribution inside a
    /// group must error out of the round, not hang the scoped join.
    #[test]
    fn hier_run_once_surfaces_shape_errors_instead_of_hanging() {
        let spec = WireSpec::new("fp", 64);
        let grads =
            vec![vec![0.5f32; 128], vec![0.5f32; 256], vec![0.5f32; 128], vec![0.5f32; 128]];
        let cfg = ExchangeConfig::hier(2, LinkMap::uniform(Link::ten_gbps()));
        let err = run_once(&cfg, &spec, &grads);
        assert!(err.is_err(), "mismatched gradient lengths must error");
    }

    #[test]
    fn streaming_config_and_driver_guards() {
        let link = Link::ten_gbps();
        // streaming rides any synchronous topology; staleness excludes it
        assert!(ExchangeConfig::flat(Topology::Ps, link).with_streaming(4).validate(2).is_ok());
        assert!(ExchangeConfig::flat(Topology::Ring, link).with_streaming(2).validate(2).is_ok());
        assert!(ExchangeConfig::sharded(2, 0, link).with_streaming(3).validate(4).is_ok());
        assert!(
            ExchangeConfig::sharded(2, 1, link).with_streaming(3).validate(4).is_err(),
            "bounded staleness excludes section streaming"
        );
        assert!(ExchangeConfig::flat(Topology::Ps, link).with_streaming(0).validate(2).is_err());
        // each driver refuses the other's config
        let grads = vec![vec![0.1f32; 256]; 2];
        let spec = WireSpec::new("terngrad", 64);
        let streaming = ExchangeConfig::flat(Topology::Ps, link).with_streaming(2);
        assert!(run_rounds(&streaming, &spec, &grads, 1).is_err());
        assert!(run_rounds_streamed(&ExchangeConfig::flat(Topology::Ps, link), &spec, &grads, 1)
            .is_err());
        // fp has no bucket grid to stream — rejected on the driver thread
        assert!(run_rounds_streamed(&streaming, &WireSpec::new("fp", 64), &grads, 1).is_err());
        // non-streaming worker ends refuse the streaming calls
        struct Dummy;
        impl WorkerExchange for Dummy {
            fn id(&self) -> usize {
                0
            }
            fn exchange(&mut self, _: &mut Vec<u8>, _: &mut Vec<f32>) -> Result<()> {
                Ok(())
            }
        }
        let mut d = Dummy;
        assert!(d.push_section(0, &[], 0.0).is_err());
        assert!(d.finish_streamed(&mut Vec::new()).is_err());
    }

    /// The tentpole bit-identity contract: ps, hier (member rings and
    /// singleton groups) and sharded-ps streamed rounds produce the same
    /// decoded means as the flat exchange over the same gradients — for
    /// every thread count (1 = the serial start-anywhere encoder) and
    /// with uplink error feedback on or off.
    #[test]
    fn streamed_bit_identical_to_flat_on_broadcast_topologies() {
        let link = Link::new(1e9, 0.0);
        let n = 3072;
        let mut rng = Rng::seed_from(31);
        let grads: Vec<Vec<f32>> = (0..4)
            .map(|_| {
                let mut g = vec![0.0f32; n];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        let cfgs = vec![
            ExchangeConfig::flat(Topology::Ps, link),
            ExchangeConfig::flat(Topology::Ps, link).with_downlink(true),
            ExchangeConfig::hier(2, LinkMap::uniform(link)),
            ExchangeConfig::hier(4, LinkMap::uniform(link)),
            ExchangeConfig::sharded(2, 0, link),
        ];
        for cfg in cfgs {
            for ef in [false, true] {
                let cfg = cfg.clone().with_error_feedback(ef);
                let spec = WireSpec { seed: 7, ..WireSpec::new("orq-5", 64) }.with_threads(2);
                let (flat_mean, _) = run_rounds(&cfg, &spec, &grads, 3).unwrap();
                for threads in [1usize, 2, 4] {
                    let spec =
                        WireSpec { seed: 7, ..WireSpec::new("orq-5", 64) }.with_threads(threads);
                    let (smean, sstats) =
                        run_rounds_streamed(&cfg.clone().with_streaming(3), &spec, &grads, 3)
                            .unwrap();
                    assert_eq!(
                        smean, flat_mean,
                        "{:?} groups={} shards={} downlink={} ef={ef} threads={threads}",
                        cfg.topology, cfg.groups, cfg.shards, cfg.quantize_downlink
                    );
                    assert!(sstats.wire_bytes > 0 && sstats.messages > 0);
                }
            }
        }
    }

    /// Encode worker 0's sections once through a collecting sink: the
    /// standalone per-section message sizes (size-deterministic — they
    /// depend only on the section lengths and the scheme) and the
    /// readiness schedule the model checks need.
    fn section_msgs(spec: &WireSpec, n: usize, sections: usize, g: &[f32]) -> (Vec<f64>, Vec<Vec<u8>>) {
        let spans: Vec<std::ops::Range<usize>> =
            (0..sections).map(|i| n * i / sections..n * (i + 1) / sections).collect();
        let map = SectionMap::new(&spans, sections, spec.bucket_size).unwrap();
        let ready = map.ready_schedule(SIM_BACKWARD_RATE);
        let mut ov = OverlapEncoder::new(spec, map).unwrap();
        let mut rng = Rng::stream(spec.seed, 2_000);
        let mut out = Vec::new();
        let mut msgs: Vec<Vec<u8>> = vec![Vec::new(); sections];
        ov.encode_streamed(
            None,
            &mut rng,
            &mut out,
            &ready,
            &mut |s, m, _| {
                msgs[s] = m.to_vec();
                Ok(())
            },
            |cb| {
                for s in spans.iter().rev() {
                    cb(s.start, g);
                }
                0.0
            },
        )
        .unwrap();
        (ready, msgs)
    }

    /// The measured/model contract of the streamed exchange: the
    /// simulator's streamed round time (measured from backward start)
    /// matches the closed-form `*_streamed_time` models to < 1% on
    /// ps / sharded-ps / hier, and with more than one section it beats
    /// "backward end + flat exchange" strictly — the overlap win the
    /// flat path cannot collect.
    #[test]
    fn streamed_sim_time_matches_models_and_beats_flat() {
        use super::super::overlap::{hier_streamed_time, ps_streamed_time, sharded_streamed_time};
        use super::super::shard::{FRAME_HEADER_BYTES, SECTION_STAMP_BYTES};
        let link = Link::new(1e8, 0.0);
        let l = 3usize;
        let n = 4096usize;
        let mut rng = Rng::seed_from(40);
        let grads: Vec<Vec<f32>> = (0..l)
            .map(|_| {
                let mut g = vec![0.0f32; n];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        let spec = WireSpec { seed: 7, ..WireSpec::new("orq-5", 128) }.with_threads(2);
        let frame = |m: &[u8]| FRAME_HEADER_BYTES + SECTION_STAMP_BYTES + m.len();

        // -- ps, 4 sections --------------------------------------------
        let sections = 4usize;
        let (ready, msgs) = section_msgs(&spec, n, sections, &grads[0]);
        let ready_send: Vec<f64> = ready.iter().rev().copied().collect();
        let frames_send: Vec<usize> = msgs.iter().rev().map(|m| frame(m)).collect();
        let scfg = ExchangeConfig::flat(Topology::Ps, link).with_streaming(sections);
        let (mean, stats) = run_rounds_streamed(&scfg, &spec, &grads, 1).unwrap();
        let mut down = Vec::new();
        codec::encode_fp_into(&mean, &mut down);
        let model = ps_streamed_time(&link, &ready_send, &frames_send, down.len());
        let err = (model - stats.sim_time_s).abs() / model;
        assert!(err < 0.01, "ps streamed model {model} vs sim {} ({err})", stats.sim_time_s);
        let (fmean, fstats) =
            run_rounds(&ExchangeConfig::flat(Topology::Ps, link), &spec, &grads, 1).unwrap();
        assert_eq!(fmean, mean, "streamed ps mean ≡ flat mean");
        let ready_last = ready.iter().copied().fold(0.0, f64::max);
        assert!(
            stats.sim_time_s < ready_last + fstats.sim_time_s,
            "streamed {} must beat backward-end + flat {}",
            stats.sim_time_s,
            ready_last + fstats.sim_time_s
        );

        // -- sharded-ps: 2 shards, 2 sections cut at the shard boundary
        // (each shard owns exactly one whole section; the other arrives
        // as an empty lockstep frame) --------------------------------
        let (ready2, msgs2) = section_msgs(&spec, n, 2, &grads[0]);
        let scfg = ExchangeConfig::sharded(2, 0, link).with_streaming(2);
        let (smean, sstats) = run_rounds_streamed(&scfg, &spec, &grads, 1).unwrap();
        assert_eq!(smean, mean, "sharded streamed mean ≡ ps mean");
        let empty = FRAME_HEADER_BYTES + SECTION_STAMP_BYTES;
        let fb = vec![
            vec![empty, frame(&msgs2[0])],
            vec![frame(&msgs2[1]), empty],
        ];
        let half = codec::encode_fp(&smean[..n / 2]).len();
        let db = vec![FRAME_HEADER_BYTES + half, FRAME_HEADER_BYTES + half];
        let ready_send2: Vec<f64> = ready2.iter().rev().copied().collect();
        let model = sharded_streamed_time(&link, &ready_send2, &fb, &db);
        let err = (model - sstats.sim_time_s).abs() / model;
        assert!(
            err < 0.01,
            "sharded streamed model {model} vs sim {} ({err})",
            sstats.sim_time_s
        );

        // -- hier with singleton groups: the leader star is the streamed
        // leg, the fp multicast the tail ------------------------------
        let lm = LinkMap::new(Link::new(1e9, 0.0), link);
        let scfg = ExchangeConfig::hier(l, lm).with_streaming(sections);
        let (hmean, hstats) = run_rounds_streamed(&scfg, &spec, &grads, 1).unwrap();
        assert_eq!(hmean, mean, "hier streamed mean ≡ ps mean");
        let mut fp = Vec::new();
        codec::encode_fp_into(&hmean, &mut fp);
        let model = hier_streamed_time(&lm, l, l, &ready_send, &frames_send, 0, fp.len());
        let err = (model - hstats.sim_time_s).abs() / model;
        assert!(err < 0.01, "hier streamed model {model} vs sim {} ({err})", hstats.sim_time_s);
    }

    /// The ring equivalence contract: streamed ring bytes are a pure
    /// function of the section schedule, so the decoded means are
    /// identical at every thread count — `threads == 1` *is* the serial
    /// replay — and a repeat run reproduces them exactly. With EF on,
    /// the per-(hop, section) residuals stay deterministic too.
    #[test]
    fn ring_streamed_thread_invariant_and_deterministic() {
        let link = Link::new(1e9, 0.0);
        let n = 2048;
        let mut rng = Rng::seed_from(17);
        let grads: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut g = vec![0.0f32; n];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        for ef in [false, true] {
            let cfg = ExchangeConfig::flat(Topology::Ring, link)
                .with_error_feedback(ef)
                .with_streaming(2);
            let mut reference: Option<Vec<f32>> = None;
            for threads in [1usize, 2, 4] {
                let spec = WireSpec { seed: 5, ..WireSpec::new("orq-5", 64) }.with_threads(threads);
                let (mean, stats) = run_rounds_streamed(&cfg, &spec, &grads, 2).unwrap();
                assert!(stats.sim_time_s > 0.0 && stats.wire_bytes > 0);
                match &reference {
                    None => reference = Some(mean),
                    Some(r) => assert_eq!(&mean, r, "ef={ef} threads={threads}"),
                }
            }
            let spec = WireSpec { seed: 5, ..WireSpec::new("orq-5", 64) };
            let (again, _) = run_rounds_streamed(&cfg, &spec, &grads, 2).unwrap();
            assert_eq!(Some(again), reference, "serial replay reproduces the run");
        }
    }
}
