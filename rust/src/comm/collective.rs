//! The `Collective` abstraction: one synchronous gradient exchange per
//! round, independent of topology.
//!
//! A topology has two ends:
//! * [`WorkerExchange`] — one per worker thread. The worker hands in its
//!   *encoded* gradient and blocks until the round's decoded mean
//!   gradient is available. Every worker receives the bit-identical mean,
//!   which is what keeps parameter replicas in sync without ever shipping
//!   parameters (paper Algorithm 2).
//! * [`Collective`] — the coordinator end, driven by the trainer's main
//!   thread. It performs whatever central work the topology needs (the
//!   parameter-server aggregation; for the ring, only bookkeeping),
//!   returns the same decoded mean, and owns the exact wire-byte and
//!   simulated-time accounting ([`CommStats`]).
//!
//! Two real implementations exist, both over `std::sync::mpsc` channels:
//! the star in [`super::ps`] and the decode-reduce-requantize ring in
//! [`super::ring`]. [`build_topology`] constructs either from a
//! [`Topology`] tag, and [`run_once`] drives a single round with scoped
//! threads — the entry point the Table 1 bench and the equivalence tests
//! use.

use crate::codec::{self, Packing};
use crate::error::{Error, Result};
use crate::quant::bucket::{BucketQuantizer, QuantizedGrad};
use crate::quant::{self, Quantizer};
use crate::tensor::rng::Rng;

use super::link::Link;
use super::ps::PsCollective;
use super::ring::RingAllReduce;

/// Which gradient-exchange topology to run.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Topology {
    /// L workers ⇄ 1 server star (paper Algorithm 2).
    #[default]
    Ps,
    /// Decentralized ring all-reduce: reduce-scatter + all-gather with
    /// decode → partial-reduce → requantize at every hop.
    Ring,
}

impl Topology {
    pub fn parse(s: &str) -> Result<Topology> {
        match s {
            "ps" | "star" => Ok(Topology::Ps),
            "ring" => Ok(Topology::Ring),
            other => Err(Error::InvalidArg(format!(
                "unknown topology {other:?} (use ps or ring)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            Topology::Ps => "ps",
            Topology::Ring => "ring",
        }
    }
}

impl std::fmt::Display for Topology {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str(self.name())
    }
}

impl std::str::FromStr for Topology {
    type Err = Error;

    fn from_str(s: &str) -> Result<Topology> {
        Topology::parse(s)
    }
}

/// Cumulative exchange accounting: exact wire bytes, simulated
/// communication seconds on the critical path, and message count.
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct CommStats {
    pub wire_bytes: u64,
    pub sim_time_s: f64,
    pub messages: u64,
}

/// Everything a topology needs to know about the wire format: how
/// gradients are quantized and packed, and the seed its internal RNG
/// streams derive from (downlink requantization, ring hop requantization).
#[derive(Debug, Clone)]
pub struct WireSpec {
    /// Quantizer name (see [`quant::from_name`]); `"fp"` disables
    /// quantization.
    pub method: String,
    /// Bucket size d; also the ring's chunk-alignment grid.
    pub bucket_size: usize,
    /// `Some(c)` applies ±c·σ clipping before level selection.
    pub clip_factor: Option<f32>,
    pub packing: Packing,
    pub seed: u64,
}

impl WireSpec {
    pub fn new(method: &str, bucket_size: usize) -> WireSpec {
        WireSpec {
            method: method.to_string(),
            bucket_size,
            clip_factor: None,
            packing: Packing::BaseS,
            seed: 0,
        }
    }
}

/// A [`WireSpec`] instantiated into a working encoder: quantizer + bucket
/// splitter + packing. Owned per node so encoding is lock-free.
pub struct GradCodec {
    method: String,
    packing: Packing,
    quantizer: Box<dyn Quantizer>,
    bucketq: BucketQuantizer,
    is_fp: bool,
}

impl GradCodec {
    pub fn new(spec: &WireSpec) -> Result<GradCodec> {
        let quantizer = quant::from_name(&spec.method)?;
        let is_fp = quantizer.num_levels() == 0;
        let bucketq = match spec.clip_factor {
            Some(c) => BucketQuantizer::with_clip(spec.bucket_size, c),
            None => BucketQuantizer::new(spec.bucket_size),
        };
        Ok(GradCodec {
            method: spec.method.clone(),
            packing: spec.packing,
            quantizer,
            bucketq,
            is_fp,
        })
    }

    pub fn is_fp(&self) -> bool {
        self.is_fp
    }

    pub fn bucket_size(&self) -> usize {
        self.bucketq.bucket_size
    }

    /// Quantize (unless FP or empty) and encode `g` into a reused message
    /// buffer. `qg` is the reusable quantization scratch — steady-state
    /// calls perform no per-bucket allocation.
    pub fn encode_into(
        &self,
        g: &[f32],
        rng: &mut Rng,
        qg: &mut QuantizedGrad,
        msg: &mut Vec<u8>,
    ) {
        if self.is_fp || g.is_empty() {
            codec::encode_fp_into(g, msg);
        } else {
            self.bucketq.quantize_into(g, self.quantizer.as_ref(), rng, qg);
            codec::encode_into(qg, &self.method, self.packing, msg);
        }
    }
}

/// Coordinator end of a topology (lives on the trainer's main thread).
pub trait Collective: Send {
    fn num_workers(&self) -> usize;

    /// Serve one synchronous exchange round and write the round's decoded
    /// mean gradient — bit-identical to what every worker's
    /// [`WorkerExchange::exchange`] returned — into `mean_out`.
    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()>;

    /// Cumulative totals since construction. Per-round figures are deltas
    /// between consecutive calls.
    fn stats(&self) -> CommStats;
}

/// Worker end of a topology (one per worker thread).
pub trait WorkerExchange: Send {
    fn id(&self) -> usize;

    /// Contribute this round's encoded gradient (the implementation may
    /// take the buffer), block for the exchange, and write the decoded
    /// mean gradient into `mean_out`.
    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()>;
}

/// The two ends of a built topology: the coordinator and one worker end
/// per worker thread.
pub type TopologyEnds = (Box<dyn Collective>, Vec<Box<dyn WorkerExchange>>);

/// Construct a topology's two ends.
pub fn build_topology(
    topology: Topology,
    workers: usize,
    link: Link,
    spec: &WireSpec,
    quantize_downlink: bool,
) -> Result<TopologyEnds> {
    match topology {
        Topology::Ps => {
            let (coord, ends) = PsCollective::new(workers, link, spec, quantize_downlink)?;
            Ok((
                Box::new(coord),
                ends.into_iter().map(|e| Box::new(e) as Box<dyn WorkerExchange>).collect(),
            ))
        }
        Topology::Ring => {
            if quantize_downlink {
                // Refuse rather than silently no-op: the flag is a PS
                // downlink option; the ring requantizes at every hop by
                // construction, so there is no broadcast to quantize.
                return Err(Error::InvalidArg(
                    "quantize_downlink applies to the parameter-server broadcast; \
                     the ring topology has no downlink (drop the flag or use --topology ps)"
                        .into(),
                ));
            }
            let (coord, ends) = RingAllReduce::new(workers, link, spec)?;
            Ok((
                Box::new(coord),
                ends.into_iter().map(|e| Box::new(e) as Box<dyn WorkerExchange>).collect(),
            ))
        }
    }
}

/// Drive one full exchange round over `grads` (one per worker) with
/// scoped worker threads: encode with the spec's quantizer, exchange,
/// return the decoded mean and the round's stats. Used by the Table 1
/// bench ("measured" columns) and the topology-equivalence tests.
pub fn run_once(
    topology: Topology,
    link: Link,
    spec: &WireSpec,
    quantize_downlink: bool,
    grads: &[Vec<f32>],
) -> Result<(Vec<f32>, CommStats)> {
    let (mut coll, ends) = build_topology(topology, grads.len(), link, spec, quantize_downlink)?;
    let mut mean = Vec::new();
    let res: Result<CommStats> = std::thread::scope(|scope| {
        for (w, mut wx) in ends.into_iter().enumerate() {
            let g: &[f32] = &grads[w];
            let spec = spec.clone();
            scope.spawn(move || {
                let gc = GradCodec::new(&spec).expect("spec validated by build_topology");
                let mut rng = Rng::stream(spec.seed, 2_000 + w as u64);
                let mut qg = QuantizedGrad::default();
                let mut msg = Vec::new();
                gc.encode_into(g, &mut rng, &mut qg, &mut msg);
                let mut mean = Vec::new();
                // On channel death the coordinator's round() surfaces the
                // real error; a panic here would only mask it.
                let _ = wx.exchange(&mut msg, &mut mean);
            });
        }
        let round = coll.round(&mut mean);
        let stats = coll.stats();
        // Tear the coordinator down before the scope joins: if round()
        // erred mid-exchange (e.g. mismatched upload shapes), workers
        // still blocked on its channels must see them close and exit
        // instead of deadlocking the join.
        drop(coll);
        round.map(|()| stats)
    });
    let stats = res?;
    Ok((mean, stats))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn topology_parse_roundtrip() {
        assert_eq!(Topology::parse("ps").unwrap(), Topology::Ps);
        assert_eq!(Topology::parse("star").unwrap(), Topology::Ps);
        assert_eq!(Topology::parse("ring").unwrap(), Topology::Ring);
        assert!(Topology::parse("mesh").is_err());
        assert_eq!(Topology::Ring.to_string(), "ring");
        assert_eq!("ps".parse::<Topology>().unwrap(), Topology::Ps);
        assert_eq!(Topology::default(), Topology::Ps);
    }

    #[test]
    fn grad_codec_fp_and_quantized() {
        let g: Vec<f32> = (0..300).map(|i| (i as f32 - 150.0) / 50.0).collect();
        let mut rng = Rng::seed_from(1);
        let mut qg = QuantizedGrad::default();
        let mut msg = Vec::new();

        let fp = GradCodec::new(&WireSpec::new("fp", 128)).unwrap();
        assert!(fp.is_fp());
        fp.encode_into(&g, &mut rng, &mut qg, &mut msg);
        assert_eq!(msg, codec::encode_fp(&g));

        let tg = GradCodec::new(&WireSpec::new("terngrad", 128)).unwrap();
        assert!(!tg.is_fp());
        assert_eq!(tg.bucket_size(), 128);
        tg.encode_into(&g, &mut rng, &mut qg, &mut msg);
        assert_eq!(
            msg.len(),
            codec::wire_size(300, 128, 3, Packing::BaseS, "terngrad")
        );
        // empty gradients fall back to the FP framing (a quantized message
        // cannot represent s levels with zero buckets)
        tg.encode_into(&[], &mut rng, &mut qg, &mut msg);
        assert!(codec::decode(&msg).unwrap().is_empty());

        assert!(GradCodec::new(&WireSpec::new("bogus", 128)).is_err());
    }

    #[test]
    fn build_topology_rejects_bad_method() {
        let spec = WireSpec::new("not-a-method", 64);
        assert!(build_topology(Topology::Ps, 2, Link::ten_gbps(), &spec, false).is_err());
        assert!(build_topology(Topology::Ring, 2, Link::ten_gbps(), &spec, false).is_err());
    }

    #[test]
    fn ring_rejects_downlink_quantization() {
        let spec = WireSpec::new("terngrad", 64);
        assert!(build_topology(Topology::Ring, 2, Link::ten_gbps(), &spec, true).is_err());
        assert!(build_topology(Topology::Ring, 2, Link::ten_gbps(), &spec, false).is_ok());
        assert!(build_topology(Topology::Ps, 2, Link::ten_gbps(), &spec, true).is_ok());
    }

    /// A coordinator-side error (mismatched upload shapes) must surface as
    /// Err, not deadlock the scoped join (regression: workers used to stay
    /// blocked on the still-open broadcast channels).
    #[test]
    fn run_once_surfaces_shape_errors_instead_of_hanging() {
        let spec = WireSpec::new("fp", 64);
        let grads = vec![vec![0.5f32; 128], vec![0.5f32; 256]];
        let err = run_once(Topology::Ps, Link::ten_gbps(), &spec, false, &grads);
        assert!(err.is_err(), "mismatched gradient lengths must error");
    }
}
