//! Ring all-reduce cost model — the decentralized alternative the paper
//! mentions ("on commercial clusters it can be conducted in a
//! decentralized ring-based all-reduce manner without the server").
//!
//! Classic bandwidth-optimal ring: each of the L nodes sends 2·(L−1)/L of
//! the buffer over its link, in 2·(L−1) serialized steps of b/L bytes.
//! Quantized gradients complicate ring reduce-scatter (sums of quantized
//! values are no longer in the codebook), so — like the paper — we use the
//! ring only as a *cost model* for FP and for decode-reduce-requantize
//! variants, to compare topologies in the Table 1 bench.

use super::link::Link;

/// Time for a ring all-reduce of `bytes` over `n` nodes.
pub fn allreduce_time(link: &Link, n: usize, bytes: usize) -> f64 {
    assert!(n > 0);
    if n == 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let chunk = bytes as f64 / n as f64;
    steps as f64 * (link.latency_s + chunk * 8.0 / link.bandwidth_bps)
}

/// Time for the parameter-server exchange of the same buffer:
/// slowest-of-L uplinks (all equal here) + one broadcast.
pub fn ps_time(link: &Link, _n: usize, up_bytes: usize, down_bytes: usize) -> f64 {
    link.transfer_time(up_bytes) + link.transfer_time(down_bytes)
}

/// Decode-reduce-requantize ring step count: every hop pays a decode and a
/// requantize, so the *message* stays small but the effective bytes per
/// hop equal the quantized size (modeled; used by the ablation bench).
pub fn quantized_ring_time(link: &Link, n: usize, quant_bytes: usize) -> f64 {
    allreduce_time(link, n, quant_bytes)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_free() {
        assert_eq!(allreduce_time(&Link::ten_gbps(), 1, 1 << 20), 0.0);
    }

    #[test]
    fn ring_asymptotically_bandwidth_optimal() {
        // As n grows, total time approaches 2 * b / bandwidth.
        let link = Link::new(1e9, 0.0);
        let b = 10_000_000usize;
        let t2 = allreduce_time(&link, 2, b);
        let t64 = allreduce_time(&link, 64, b);
        let optimal = 2.0 * (b as f64) * 8.0 / 1e9;
        assert!((t2 - optimal * 0.5).abs() < 1e-9); // 2 nodes: (2·1/2)·b
        assert!((t64 - optimal).abs() / optimal < 0.05, "t64={t64} opt={optimal}");
    }

    #[test]
    fn latency_scales_with_steps() {
        let link = Link::new(1e12, 0.001); // latency-dominated
        let t4 = allreduce_time(&link, 4, 1000);
        let t8 = allreduce_time(&link, 8, 1000);
        assert!((t4 - 0.006).abs() < 1e-6);
        assert!((t8 - 0.014).abs() < 1e-6);
    }

    #[test]
    fn ps_vs_ring_crossover() {
        // Small clusters: PS (2 transfers of full buffer) ≈ ring; the ring
        // wins on latency-free links for large n because each node only
        // moves 2(n-1)/n of the buffer — but PS with multicast broadcast
        // moves 2 full buffers regardless of n.
        let link = Link::new(1e9, 0.0);
        let b = 1_000_000usize;
        let ring = allreduce_time(&link, 16, b);
        let ps = ps_time(&link, 16, b, b);
        assert!(ring < ps * 1.05, "ring {ring} should not lose badly to ps {ps}");
    }
}
