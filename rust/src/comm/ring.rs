//! Ring all-reduce — the decentralized alternative the paper mentions
//! ("on commercial clusters it can be conducted in a decentralized
//! ring-based all-reduce manner without the server").
//!
//! Two layers live here:
//!
//! * **Closed-form cost model** ([`allreduce_time`], [`ps_time`],
//!   [`quantized_ring_time`]) — the classic bandwidth-optimal figures the
//!   Table 1 bench prints next to the measured numbers.
//! * **Executable topology** ([`RingAllReduce`]/[`RingWorker`]) — a real
//!   ring over per-hop `std::sync::mpsc` channels implementing the
//!   [`Collective`]/[`WorkerExchange`] interface. Each node owns one edge
//!   to its successor; a round is the standard reduce-scatter +
//!   all-gather, `2·(L−1)` serialized steps of one chunk each.
//!
//! **Decode-reduce-requantize semantics.** Quantized partial sums are not
//! closed under addition (sums of codebook values leave the codebook), so
//! every reduce-scatter hop decodes the incoming chunk, adds its own
//! decoded contribution, requantizes the partial sum with its own RNG
//! stream, and forwards the re-encoded bytes. Chunks are aligned to the
//! bucket grid so each node's *first* transmission is a byte slice of its
//! original encoded gradient ([`crate::codec::slice_elements_into`]) —
//! no spurious extra quantization before the first reduction. All-gather
//! then forwards the final encoded chunks unchanged, which is what makes
//! the decoded mean bit-identical on every node (the property the trainer
//! relies on to keep parameter replicas in sync). FP gradients take the
//! same path losslessly.
//!
//! **Per-hop error feedback.** With `error_feedback` on, every
//! requantization site keeps its own [`ErrorFeedback`] residual — one per
//! reduce-scatter hop position, since hop `k` always requantizes the same
//! chunk index for a given worker and compensates a *different* partial
//! sum than hop `k + 1`. The residual carries what hop `k`'s quantization
//! dropped in round `t` into round `t + 1`'s hop-`k` encode, so the
//! per-hop bias of biased schemes (BinGrad, signSGD) no longer compounds
//! with hop count across rounds. All-gather forwarding is untouched, so
//! the bit-identity property is preserved verbatim.
//!
//! **Codec threads.** Each worker's [`GradCodec`] honors
//! `WireSpec::threads`: with a parallel codec the per-hop requantization
//! runs the bucket pipeline (per-bucket RNG streams — still fully
//! deterministic per worker, and thread-count invariant).
//!
//! **Accounting.** Wire bytes are the exact encoded sizes of every hop
//! message (they match [`crate::codec::wire_size`] per chunk).
//! Simulated time is the critical path under the synchronous-step model:
//! per step all L nodes transmit concurrently, so the step costs
//! `max_w transfer_time(bytes_w)`; the round is the sum over the
//! `2·(L−1)` steps. Workers report per-step byte traces to the
//! coordinator, which does the max/sum — the coordinator itself moves no
//! gradient data (there is no server in a ring).
//!
//! **Streaming.** With `ExchangeConfig::with_streaming` each staged
//! overlap section runs its *own* complete reduce-scatter + all-gather
//! the moment [`WorkerExchange::push_section`] delivers it — sections
//! execute serially in the deterministic descending send schedule, so
//! the blocking per-hop recvs stay in lockstep across the ring. The
//! first hop of every section is a [`FrameKind::Section`]-framed slice
//! of the section message (the receiver validates round, sender and
//! section index — a diverged schedule errors instead of deadlocking);
//! later hops are the usual raw requantized chunks. Every (hop,
//! section) requantization site keeps its own error-feedback residual.
//! A streamed ring round is NOT bit-identical to the flat round (each
//! section is reduced on its own chunk grid with more requantization
//! sites); its contract is determinism — the streamed mean is a pure
//! function of the section schedule, identical for any worker thread
//! count, and `threads == 1` *is* the serial replay of the same
//! schedule. Simulated time: section i's hops cannot start before the
//! slowest worker has staged it (`max_w ready`), then the usual
//! max-transfer-per-step sum over its `2·(L−1)` steps.

use std::ops::Range;
use std::sync::mpsc::{channel, Receiver, Sender};

use super::collective::{
    collect_traces, Collective, CommStats, GradCodec, RoundTrace, WireSpec, WorkerExchange,
};
use super::link::{Link, LinkMap, TrafficMeter};
use super::ps::SECTION_MSG_OFFSET;
use super::shard::{begin_frame_into, finish_frame, parse_frame, split_section_payload, FrameKind};
use crate::codec;
use crate::error::{Error, Result};
use crate::quant::bucket::QuantizedGrad;
use crate::quant::error_feedback::ErrorFeedback;
use crate::tensor::rng::Rng;

// --------------------------------------------------------------------
// Closed-form cost model (Table 1's modeled columns)
// --------------------------------------------------------------------

/// Time for a ring all-reduce of `bytes` over `n` nodes.
pub fn allreduce_time(link: &Link, n: usize, bytes: usize) -> f64 {
    assert!(n > 0);
    if n == 1 {
        return 0.0;
    }
    let steps = 2 * (n - 1);
    let chunk = bytes as f64 / n as f64;
    steps as f64 * (link.latency_s + chunk * 8.0 / link.bandwidth_bps)
}

/// Time for the parameter-server exchange of the same buffer:
/// slowest-of-L uplinks (all equal here) + one broadcast.
pub fn ps_time(link: &Link, _n: usize, up_bytes: usize, down_bytes: usize) -> f64 {
    link.transfer_time(up_bytes) + link.transfer_time(down_bytes)
}

/// Decode-reduce-requantize ring step count: every hop pays a decode and a
/// requantize, so the *message* stays small but the effective bytes per
/// hop equal the quantized size (modeled; the executable [`RingAllReduce`]
/// measures the same quantity with exact per-chunk header overhead).
pub fn quantized_ring_time(link: &Link, n: usize, quant_bytes: usize) -> f64 {
    allreduce_time(link, n, quant_bytes)
}

// --------------------------------------------------------------------
// Executable ring
// --------------------------------------------------------------------

/// Element range of ring chunk `i` (of `parts`) for a gradient of `total`
/// elements, aligned to the `bucket`-sized quantization grid so encoded
/// messages can be sliced and requantized per chunk without re-bucketing.
pub fn chunk_range(total: usize, bucket: usize, parts: usize, i: usize) -> Range<usize> {
    debug_assert!(parts > 0 && bucket > 0 && i < parts);
    let b = total.div_ceil(bucket); // buckets in the grid
    let lo = (b * i / parts) * bucket;
    let hi = (b * (i + 1) / parts) * bucket;
    lo.min(total)..hi.min(total)
}

/// `(a − b) mod l` without underflow, for `b ≤ l`.
pub(crate) fn ring_sub(a: usize, b: usize, l: usize) -> usize {
    (a + l - b) % l
}

/// Coordinator end of the ring: pure bookkeeping (critical-path time,
/// exact wire bytes) plus relaying worker 0's decoded mean to the
/// trainer. No gradient bytes flow through it.
pub struct RingAllReduce {
    workers: usize,
    link: Link,
    trace_rx: Receiver<RoundTrace>,
    mean_rx: Receiver<Vec<f32>>,
    meter: TrafficMeter,
    sim_time_s: f64,
    /// Closed-form [`allreduce_time`] accumulated per round for the
    /// obs drift section — the flat model ignores per-chunk headers and
    /// bucket-grid rounding, so (unlike the star) the ring reports a
    /// small *genuine* model error.
    model_time_s: f64,
    /// `Some(sections)` when the ring was built for section streaming.
    streaming: Option<usize>,
    recorder: crate::obs::TraceRecorder,
}

impl RingAllReduce {
    /// Build the ring: edge `w → (w+1) mod L` for every worker. Ring
    /// edges connect distinct single-worker groups, so the ring uses the
    /// *inter* link of the per-edge-class map. With `streaming =
    /// Some(sections)` the ends only accept the
    /// `push_section`/`finish_streamed` protocol (one reduce-scatter +
    /// all-gather per section, per-(hop, section) EF residuals).
    pub fn new(
        workers: usize,
        links: LinkMap,
        spec: &WireSpec,
        error_feedback: bool,
        streaming: Option<usize>,
    ) -> Result<(RingAllReduce, Vec<RingWorker>)> {
        let link = links.inter;
        if workers == 0 {
            return Err(Error::InvalidArg("ring needs at least 1 worker".into()));
        }
        if let Some(nsec) = streaming {
            if nsec == 0 || nsec > u16::MAX as usize {
                return Err(Error::InvalidArg(format!(
                    "ring streaming needs 1..={} sections, got {nsec}",
                    u16::MAX
                )));
            }
        }
        // Validate the spec up front (quantizer name) before spawning ends.
        let probe = GradCodec::new(spec)?;
        // One residual per requantization site. Flat rounds have one site
        // per reduce-scatter hop position; streamed rounds run a full
        // reduce-scatter per section, so each (hop, section) pair is its
        // own site (indexed `k * sections + section`).
        let hops_ef = if error_feedback && !probe.is_fp() {
            workers.saturating_sub(1) * streaming.unwrap_or(1)
        } else {
            0
        };
        let (trace_tx, trace_rx) = channel::<RoundTrace>();
        let (mean_tx, mean_rx) = channel::<Vec<f32>>();
        let mut txs: Vec<Option<Sender<Vec<u8>>>> = Vec::with_capacity(workers);
        let mut rxs: Vec<Option<Receiver<Vec<u8>>>> = Vec::with_capacity(workers);
        for _ in 0..workers {
            let (tx, rx) = channel::<Vec<u8>>();
            txs.push(Some(tx));
            rxs.push(Some(rx));
        }
        let mut ends = Vec::with_capacity(workers);
        for w in 0..workers {
            let codec = GradCodec::new(spec)?;
            let hop_ef = (0..hops_ef).map(|_| codec.error_feedback()).collect();
            ends.push(RingWorker {
                id: w,
                workers,
                tx_next: txs[(w + 1) % workers].take().expect("edge assigned once"),
                rx_prev: rxs[w].take().expect("inbox assigned once"),
                trace_tx: trace_tx.clone(),
                mean_tx: if w == 0 { Some(mean_tx.clone()) } else { None },
                codec,
                hop_ef,
                rng: Rng::stream(spec.seed, 4_000 + w as u64),
                own: Vec::new(),
                chunk: Vec::new(),
                qg: QuantizedGrad::default(),
                step_bytes: Vec::new(),
                streaming,
                round: 0,
                sec_means: Vec::new(),
                sec_done: Vec::new(),
                stream_rows: Vec::new(),
                last_msg_bytes: 0,
                wscratch: Vec::new(),
            });
        }
        Ok((
            RingAllReduce {
                workers,
                link,
                trace_rx,
                mean_rx,
                meter: TrafficMeter::default(),
                sim_time_s: 0.0,
                model_time_s: 0.0,
                streaming,
                recorder: spec.recorder.clone(),
            },
            ends,
        ))
    }
}

impl Collective for RingAllReduce {
    fn num_workers(&self) -> usize {
        self.workers
    }

    fn round(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let l = self.workers;
        let hops = if l > 1 { 2 * (l - 1) } else { 0 };
        match self.streaming {
            None => {
                let traces = collect_traces(&self.trace_rx, l, hops, 0, "ring")?;
                let fine = self.recorder.is_fine();
                // Synchronous-step critical path: all nodes transmit
                // concurrently within a step, steps serialize.
                for k in 0..hops {
                    let mut step = 0.0f64;
                    for tr in &traces {
                        let bytes = tr.step_bytes[k];
                        step = step.max(self.link.transfer_time(bytes));
                        // Reduce-scatter hops move data toward the
                        // aggregated chunks (up); all-gather hops
                        // distribute them back (down).
                        if k < l - 1 {
                            self.meter.record_up(&self.link, bytes);
                        } else {
                            self.meter.record_down(&self.link, bytes);
                        }
                    }
                    if fine {
                        let name = if k < l - 1 { "rs_hop" } else { "ag_hop" };
                        let c = crate::obs::Track::Coordinator;
                        self.recorder.begin_sim(c, name, self.sim_time_s);
                        self.recorder.end_sim(c, name, self.sim_time_s + step);
                    }
                    self.sim_time_s += step;
                }
                // Model the round as one all-reduce of the largest flat
                // message — the Table 1 closed form.
                let msg = traces.iter().map(|tr| tr.msg_bytes).max().unwrap_or(0);
                self.model_time_s += allreduce_time(&self.link, l, msg);
            }
            Some(nsec) => {
                // One full reduce-scatter + all-gather per section, in push
                // order: section i's first hop cannot start before the
                // slowest worker has staged it (stream row i's ready
                // stamp), then its `2·(L−1)` steps pay the usual
                // max-transfer critical path. Stream rows carry readiness
                // only — every wire byte is in `step_bytes`.
                let traces = collect_traces(&self.trace_rx, l, nsec * hops, nsec, "ring")?;
                let fine = self.recorder.is_fine();
                let base = self.sim_time_s;
                let mut t = 0.0f64;
                let mut tm = 0.0f64;
                for i in 0..nsec {
                    let gate =
                        traces.iter().map(|tr| tr.stream[i].0).fold(0.0f64, f64::max);
                    t = t.max(gate);
                    if fine {
                        let c = crate::obs::Track::Coordinator;
                        self.recorder.instant_sim(c, "section_ready", base + gate);
                        self.recorder.begin_sim(c, "section_collective", base + t);
                    }
                    for k in 0..hops {
                        let mut step = 0.0f64;
                        for tr in &traces {
                            let bytes = tr.step_bytes[i * hops + k];
                            step = step.max(self.link.transfer_time(bytes));
                            if k < l - 1 {
                                self.meter.record_up(&self.link, bytes);
                            } else {
                                self.meter.record_down(&self.link, bytes);
                            }
                        }
                        t += step;
                    }
                    if fine {
                        let c = crate::obs::Track::Coordinator;
                        self.recorder.end_sim(c, "section_collective", base + t);
                    }
                    // Streamed model: the section's all-reduce of its
                    // largest payload, gated on the slowest stage.
                    let sec_msg =
                        traces.iter().map(|tr| tr.stream[i].1).max().unwrap_or(0);
                    tm = tm.max(gate) + allreduce_time(&self.link, l, sec_msg);
                }
                self.sim_time_s += t;
                self.model_time_s += tm;
            }
        }
        let mean = self
            .mean_rx
            .recv()
            .map_err(|_| Error::Comm("ring worker 0 died before reporting the mean".into()))?;
        mean_out.clear();
        mean_out.extend_from_slice(&mean);
        Ok(())
    }

    fn stats(&self) -> CommStats {
        CommStats {
            wire_bytes: self.meter.total_bytes(),
            wire_bytes_intra: 0,
            wire_bytes_inter: self.meter.total_bytes(),
            wire_bytes_up: self.meter.bytes_up,
            wire_bytes_down: self.meter.bytes_down,
            sim_time_s: self.sim_time_s,
            model_time_s: self.model_time_s,
            messages: self.meter.messages,
            staleness: Default::default(),
        }
    }
}

/// Worker end of the ring. All scratch (decoded own gradient, chunk
/// accumulator, requantization state, decode scratch) is reused across
/// rounds; hop buffers are recycled through the channels (each received
/// message buffer becomes the next send).
pub struct RingWorker {
    id: usize,
    workers: usize,
    tx_next: Sender<Vec<u8>>,
    rx_prev: Receiver<Vec<u8>>,
    trace_tx: Sender<RoundTrace>,
    mean_tx: Option<Sender<Vec<f32>>>,
    codec: GradCodec,
    /// Per-site error-feedback residuals; empty when EF is off or the
    /// codec is FP. Flat rounds: `hop_ef[k]` compensates the
    /// reduce-scatter hop-`k` requantization. Streamed rounds:
    /// `hop_ef[k * sections + section]` — each (hop, section) pair is a
    /// distinct requantization site.
    hop_ef: Vec<ErrorFeedback>,
    rng: Rng,
    own: Vec<f32>,
    chunk: Vec<f32>,
    qg: QuantizedGrad,
    step_bytes: Vec<usize>,
    /// `Some(sections)` when built for streaming.
    streaming: Option<usize>,
    round: u64,
    /// Per-section decoded means, concatenated at `finish_streamed`.
    sec_means: Vec<Vec<f32>>,
    /// Which sections have been pushed this round (duplicate guard).
    sec_done: Vec<bool>,
    /// `(ready, payload_bytes)` per pushed section, in push order; the
    /// readiness gates the coordinator's per-section timing and the
    /// payload size feeds the per-section model (every wire byte still
    /// lives in `step_bytes`).
    stream_rows: Vec<(f64, usize)>,
    /// The flat round's encoded message size, reported in the round
    /// trace for the coordinator's closed-form model (0 when streamed).
    last_msg_bytes: usize,
    /// Width table captured from the incoming hop message (budgeted
    /// rounds) — the widths the requantization must reproduce, read from
    /// the frame, never derived locally.
    wscratch: Vec<u8>,
}

impl RingWorker {
    fn send(&mut self, msg: Vec<u8>) -> Result<()> {
        self.step_bytes.push(msg.len());
        self.tx_next
            .send(msg)
            .map_err(|_| Error::Comm("ring successor hung up".into()))
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.rx_prev
            .recv()
            .map_err(|_| Error::Comm("ring predecessor hung up".into()))
    }

    /// Decode `msg` into the chunk scratch and verify it matches chunk `c`.
    /// Routed through [`GradCodec`] so a parallel `WireSpec` decodes hop
    /// chunks on the worker pool too (split field borrows: the codec
    /// writes into the chunk scratch while both live in `self`).
    fn decode_chunk(&mut self, msg: &[u8], c: usize, total: usize) -> Result<()> {
        let RingWorker { codec, chunk, .. } = self;
        codec.decode_flat_into(msg, chunk)?;
        let want = chunk_range(total, codec.bucket_size(), self.workers, c).len();
        if self.chunk.len() != want {
            return Err(Error::Comm(format!(
                "ring chunk {c} decoded to {} elements, expected {want}",
                self.chunk.len()
            )));
        }
        Ok(())
    }

    /// Validate a hop-0 section frame from the ring predecessor: kind,
    /// round, sender, section slot and ready stamp. All workers run the
    /// same deterministic section schedule; this check turns a diverged
    /// schedule into an error at the first hop instead of a deadlock or
    /// a silently corrupt reduction.
    fn check_section_frame(&self, bytes: &[u8], section: usize, nsec: usize) -> Result<()> {
        let f = parse_frame(bytes)?;
        if f.kind != FrameKind::Section {
            return Err(Error::Comm(format!(
                "ring hop-0 frame has kind {:?}, want Section",
                f.kind
            )));
        }
        if f.round != self.round {
            return Err(Error::Comm(format!(
                "ring section frame from round {}, want round {}",
                f.round, self.round
            )));
        }
        let pred = (self.id + self.workers - 1) % self.workers;
        if f.sender as usize != pred {
            return Err(Error::Comm(format!(
                "ring section frame from worker {}, want predecessor {pred}",
                f.sender
            )));
        }
        if f.slot as usize != section {
            return Err(Error::Comm(format!(
                "ring section schedule diverged: predecessor sent section {} while this \
                 worker is on section {section} (of {nsec})",
                f.slot
            )));
        }
        split_section_payload(f.payload)?;
        Ok(())
    }

    fn finish_round(&mut self, mean: &[f32]) -> Result<()> {
        let trace = RoundTrace {
            worker: self.id,
            step_bytes: std::mem::take(&mut self.step_bytes),
            stream: std::mem::take(&mut self.stream_rows),
            msg_bytes: std::mem::take(&mut self.last_msg_bytes),
        };
        self.trace_tx
            .send(trace)
            .map_err(|_| Error::Comm("ring coordinator hung up".into()))?;
        if let Some(tx) = &self.mean_tx {
            tx.send(mean.to_vec())
                .map_err(|_| Error::Comm("ring coordinator hung up".into()))?;
        }
        Ok(())
    }
}

impl WorkerExchange for RingWorker {
    fn id(&self) -> usize {
        self.id
    }

    fn exchange(&mut self, encoded: &mut Vec<u8>, mean_out: &mut Vec<f32>) -> Result<()> {
        if self.streaming.is_some() {
            return Err(Error::InvalidArg(
                "this ring was built for streaming; use push_section/finish_streamed".into(),
            ));
        }
        let l = self.workers;
        let w = self.id;
        let d = self.codec.bucket_size();
        // Own contribution, decoded once: what this node adds at each hop
        // (codec-routed, so the parallel pipeline shards this full-size
        // decode exactly like the PS paths).
        {
            let RingWorker { codec, own, .. } = self;
            codec.decode_flat_into(encoded, own)?;
        }
        let n = self.own.len();
        mean_out.clear();
        self.step_bytes.clear();
        self.last_msg_bytes = encoded.len();
        if l == 1 {
            // Nothing to exchange: the mean of one contribution is itself.
            mean_out.extend_from_slice(&self.own);
            return self.finish_round(mean_out);
        }
        mean_out.resize(n, 0.0);

        // ---- reduce-scatter: L−1 hops of decode → add → requantize ----
        // Step 0 ships a byte slice of the original encoded gradient.
        let mut cur = Vec::new();
        let r = chunk_range(n, d, l, w);
        codec::slice_elements_into(encoded, r.start, r.end, &mut cur)?;
        for k in 0..l - 1 {
            self.send(cur)?;
            let mut msg = self.recv()?;
            let c = ring_sub(w, k + 1, l);
            self.decode_chunk(&msg, c, n)?;
            let r = chunk_range(n, d, l, c);
            for (a, v) in self.chunk.iter_mut().zip(&self.own[r]) {
                *a += *v;
            }
            // Requantize the partial (or, on the last hop, final) sum for
            // transmission, recycling the received buffer. With EF on, the
            // hop's residual compensates what round t−1's hop-k encode
            // dropped. Budgeted rounds requantize at the widths decoded
            // from the incoming message's in-band table.
            let has_w = codec::capture_widths(&msg, &mut self.wscratch)?;
            let widths = has_w.then_some(&self.wscratch[..]);
            match self.hop_ef.get_mut(k) {
                Some(ef) => self.codec.encode_matched_ef_into(
                    widths,
                    ef,
                    &self.chunk,
                    &mut self.rng,
                    &mut self.qg,
                    &mut msg,
                )?,
                None => self.codec.encode_matched_into(
                    widths,
                    &self.chunk,
                    &mut self.rng,
                    &mut self.qg,
                    &mut msg,
                )?,
            }
            cur = msg;
        }

        // `cur` is the complete encoded sum of chunk (w+1) mod L; every
        // node decodes the *same bytes* per chunk, so the mean is
        // bit-identical ring-wide.
        let c0 = (w + 1) % l;
        self.decode_chunk(&cur, c0, n)?;
        let r0 = chunk_range(n, d, l, c0);
        mean_out[r0].copy_from_slice(&self.chunk);

        // ---- all-gather: L−1 forwarding hops, no requantization ----
        for k in 0..l - 1 {
            self.send(cur)?;
            let msg = self.recv()?;
            let c = ring_sub(w, k, l);
            self.decode_chunk(&msg, c, n)?;
            let r = chunk_range(n, d, l, c);
            mean_out[r].copy_from_slice(&self.chunk);
            cur = msg;
        }

        let inv = 1.0 / l as f32;
        for v in mean_out.iter_mut() {
            *v *= inv;
        }
        self.finish_round(mean_out)
    }

    /// Run section `section`'s complete reduce-scatter + all-gather right
    /// now. All workers push the same deterministic section schedule, so
    /// the blocking per-hop recvs stay in lockstep; the first hop is
    /// Section-framed and validated so a diverged schedule errors instead
    /// of deadlocking.
    fn push_section(&mut self, section: usize, payload: &[u8], ready_s: f64) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this ring was not built for streaming; rebuild with ExchangeConfig::with_streaming".into(),
            ));
        };
        if section >= nsec {
            return Err(Error::InvalidArg(format!(
                "section {section} out of range (sections={nsec})"
            )));
        }
        if !ready_s.is_finite() || ready_s < 0.0 {
            return Err(Error::InvalidArg(format!(
                "bad ready stamp {ready_s} for section {section}"
            )));
        }
        if self.sec_means.is_empty() {
            self.sec_means = vec![Vec::new(); nsec];
            self.sec_done = vec![false; nsec];
        }
        if self.sec_done[section] {
            return Err(Error::InvalidArg(format!(
                "section {section} pushed twice in round {}",
                self.round
            )));
        }
        self.sec_done[section] = true;
        self.stream_rows.push((ready_s, payload.len()));

        let l = self.workers;
        let w = self.id;
        let d = self.codec.bucket_size();
        // This worker's contribution to the section, decoded once.
        {
            let RingWorker { codec, own, .. } = self;
            codec.decode_flat_into(payload, own)?;
        }
        let sn = self.own.len();
        let mut sec_mean = std::mem::take(&mut self.sec_means[section]);
        sec_mean.clear();
        if l == 1 {
            sec_mean.extend_from_slice(&self.own);
            self.sec_means[section] = sec_mean;
            return Ok(());
        }
        sec_mean.resize(sn, 0.0);

        // ---- reduce-scatter over the section's own chunk grid ----
        // Hop 0 ships a Section-framed byte slice of the section message;
        // later hops are raw requantized chunks, as in the flat round.
        let mut cur = Vec::new();
        let r = chunk_range(sn, d, l, w);
        begin_frame_into(FrameKind::Section, self.round, section as u16, w as u16, &mut cur);
        cur.extend_from_slice(&ready_s.to_le_bytes());
        codec::slice_elements_append(payload, r.start, r.end, &mut cur)?;
        finish_frame(&mut cur);
        for k in 0..l - 1 {
            self.send(cur)?;
            let mut msg = self.recv()?;
            let body = if k == 0 {
                self.check_section_frame(&msg, section, nsec)?;
                SECTION_MSG_OFFSET
            } else {
                0
            };
            let c = ring_sub(w, k + 1, l);
            {
                let RingWorker { codec, chunk, .. } = self;
                codec.decode_flat_into(&msg[body..], chunk)?;
            }
            let r = chunk_range(sn, d, l, c);
            if self.chunk.len() != r.len() {
                return Err(Error::Comm(format!(
                    "ring section {section} chunk {c} decoded to {} elements, expected {}",
                    self.chunk.len(),
                    r.len()
                )));
            }
            for (a, v) in self.chunk.iter_mut().zip(&self.own[r]) {
                *a += *v;
            }
            // Requantize the partial sum, recycling the received buffer.
            // Each (hop, section) pair keeps its own EF residual; on
            // budgeted rounds the widths come from the incoming frame.
            let has_w = codec::capture_widths(&msg[body..], &mut self.wscratch)?;
            let widths = has_w.then_some(&self.wscratch[..]);
            match self.hop_ef.get_mut(k * nsec + section) {
                Some(ef) => self.codec.encode_matched_ef_into(
                    widths,
                    ef,
                    &self.chunk,
                    &mut self.rng,
                    &mut self.qg,
                    &mut msg,
                )?,
                None => self.codec.encode_matched_into(
                    widths,
                    &self.chunk,
                    &mut self.rng,
                    &mut self.qg,
                    &mut msg,
                )?,
            }
            cur = msg;
        }

        // `cur` is the complete encoded section sum of chunk (w+1) mod L.
        let c0 = (w + 1) % l;
        self.decode_chunk(&cur, c0, sn)?;
        let r0 = chunk_range(sn, d, l, c0);
        sec_mean[r0].copy_from_slice(&self.chunk);

        // ---- all-gather: forwarding only, no requantization ----
        for k in 0..l - 1 {
            self.send(cur)?;
            let msg = self.recv()?;
            let c = ring_sub(w, k, l);
            self.decode_chunk(&msg, c, sn)?;
            let r = chunk_range(sn, d, l, c);
            sec_mean[r].copy_from_slice(&self.chunk);
            cur = msg;
        }

        let inv = 1.0 / l as f32;
        for v in sec_mean.iter_mut() {
            *v *= inv;
        }
        self.sec_means[section] = sec_mean;
        Ok(())
    }

    fn finish_streamed(&mut self, mean_out: &mut Vec<f32>) -> Result<()> {
        let Some(nsec) = self.streaming else {
            return Err(Error::InvalidArg(
                "this ring was not built for streaming; rebuild with ExchangeConfig::with_streaming".into(),
            ));
        };
        if self.sec_means.is_empty() {
            self.sec_means = vec![Vec::new(); nsec];
            self.sec_done = vec![false; nsec];
        }
        if let Some(missing) = self.sec_done.iter().position(|done| !done) {
            return Err(Error::InvalidArg(format!(
                "finish_streamed before section {missing} was pushed in round {}",
                self.round
            )));
        }
        mean_out.clear();
        for sec in &self.sec_means {
            mean_out.extend_from_slice(sec);
        }
        for done in self.sec_done.iter_mut() {
            *done = false;
        }
        self.round += 1;
        self.finish_round(mean_out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn single_node_free() {
        assert_eq!(allreduce_time(&Link::ten_gbps(), 1, 1 << 20), 0.0);
    }

    #[test]
    fn ring_asymptotically_bandwidth_optimal() {
        // As n grows, total time approaches 2 * b / bandwidth.
        let link = Link::new(1e9, 0.0);
        let b = 10_000_000usize;
        let t2 = allreduce_time(&link, 2, b);
        let t64 = allreduce_time(&link, 64, b);
        let optimal = 2.0 * (b as f64) * 8.0 / 1e9;
        assert!((t2 - optimal * 0.5).abs() < 1e-9); // 2 nodes: (2·1/2)·b
        assert!((t64 - optimal).abs() / optimal < 0.05, "t64={t64} opt={optimal}");
    }

    #[test]
    fn latency_scales_with_steps() {
        let link = Link::new(1e12, 0.001); // latency-dominated
        let t4 = allreduce_time(&link, 4, 1000);
        let t8 = allreduce_time(&link, 8, 1000);
        assert!((t4 - 0.006).abs() < 1e-6);
        assert!((t8 - 0.014).abs() < 1e-6);
    }

    #[test]
    fn ps_vs_ring_crossover() {
        // Small clusters: PS (2 transfers of full buffer) ≈ ring; the ring
        // wins on latency-free links for large n because each node only
        // moves 2(n-1)/n of the buffer — but PS with multicast broadcast
        // moves 2 full buffers regardless of n.
        let link = Link::new(1e9, 0.0);
        let b = 1_000_000usize;
        let ring = allreduce_time(&link, 16, b);
        let ps = ps_time(&link, 16, b, b);
        assert!(ring < ps * 1.05, "ring {ring} should not lose badly to ps {ps}");
    }

    #[test]
    fn chunk_ranges_cover_and_align() {
        for (total, bucket, parts) in
            [(1000usize, 128usize, 4usize), (100, 64, 4), (5, 2, 8), (0, 16, 3), (4096, 512, 1)]
        {
            let mut covered = 0usize;
            for i in 0..parts {
                let r = chunk_range(total, bucket, parts, i);
                assert_eq!(r.start, covered, "contiguous at {total}/{bucket}/{parts}");
                assert!(r.start % bucket == 0 || r.start == total, "aligned start");
                assert!(r.end % bucket == 0 || r.end == total, "aligned end");
                covered = r.end;
            }
            assert_eq!(covered, total, "full cover at {total}/{bucket}/{parts}");
        }
    }

    #[test]
    fn ring_sub_wraps() {
        assert_eq!(ring_sub(0, 1, 4), 3);
        assert_eq!(ring_sub(3, 3, 4), 0);
        assert_eq!(ring_sub(2, 0, 4), 2);
        assert_eq!(ring_sub(1, 4, 4), 1);
    }

    /// The exact bytes of a chunk-sized message through the serial
    /// scratch decoder and the pooled pipeline decoder: the pipeline
    /// chunk decode (new in the codec-routed `decode_chunk`) must be a
    /// pure speedup, bit-identical to the serial path it replaced.
    #[test]
    fn pipeline_chunk_decode_matches_serial_decode() {
        for n in [96usize, 1000] {
            let g: Vec<f32> =
                (0..n).map(|i| ((i * 13) % 31) as f32 / 31.0 - 0.5).collect();
            let mut enc =
                GradCodec::new(&WireSpec::new("terngrad", 64).with_threads(2)).unwrap();
            let mut rng = Rng::stream(7, 0);
            let mut qg = QuantizedGrad::default();
            let mut msg = Vec::new();
            enc.encode_into(&g, &mut rng, &mut qg, &mut msg);
            let mut serial = GradCodec::new(&WireSpec::new("terngrad", 64)).unwrap();
            let mut par =
                GradCodec::new(&WireSpec::new("terngrad", 64).with_threads(4)).unwrap();
            let (mut a, mut b) = (Vec::new(), Vec::new());
            serial.decode_flat_into(&msg, &mut a).unwrap();
            par.decode_flat_into(&msg, &mut b).unwrap();
            assert_eq!(a.len(), n);
            assert_eq!(a, b, "pipeline decode diverged from serial at n={n}");
        }
    }

    /// Full ring rounds with codec-routed chunk decode: the per-bucket
    /// encode streams are thread-count invariant and decode is
    /// deterministic, so the ring mean must match bit for bit across
    /// every parallel thread count, quantized and fp.
    #[test]
    fn ring_mean_bit_identical_across_decode_thread_counts() {
        use super::super::collective::{run_once, ExchangeConfig, Topology};
        let workers = 4;
        let n = 1000; // ragged final bucket on the 64 grid
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|w| {
                (0..n)
                    .map(|i| ((i * 37 + w * 101) % 997) as f32 / 997.0 - 0.5)
                    .collect()
            })
            .collect();
        let cfg = ExchangeConfig::flat(Topology::Ring, Link::ten_gbps());
        for method in ["terngrad", "fp"] {
            let mut reference: Option<Vec<f32>> = None;
            for threads in [2usize, 3, 4] {
                let spec = WireSpec::new(method, 64).with_threads(threads);
                let (mean, _) = run_once(&cfg, &spec, &grads).unwrap();
                assert_eq!(mean.len(), n);
                match &reference {
                    None => reference = Some(mean),
                    Some(r) => assert_eq!(
                        r, &mean,
                        "{method} ring mean diverged at {threads} threads"
                    ),
                }
            }
        }
    }
}
