//! Layer-wise gradient bucketing with backward/communication overlap.
//!
//! Real DDP stacks hide communication behind compute by bucketing the
//! gradient per model section and shipping early buckets while later
//! layers are still differentiating. This module brings that structure
//! to the trainer without giving up the repo's bit-identity contract:
//!
//! * [`SectionMap`] — the model-section bucket map, seeded from the
//!   backend's layer structure ([`crate::model::Backend::layer_spans`]).
//!   The map cuts the bucket grid at layer-group boundaries so every
//!   bucket belongs to exactly one section; a bucket straddling a
//!   boundary is owned by the *lower* section, because backward produces
//!   gradients in reverse layer order and the straddling bucket is only
//!   complete once the lower section's layers are done. Section `i` is
//!   therefore ready exactly when the backward frontier reaches its
//!   first owned element.
//! * [`OverlapEncoder`] — the overlap driver. It replicates the parallel
//!   codec's encode exactly — one round key drawn per step, per-bucket
//!   RNG streams keyed by the *global* bucket index
//!   ([`BucketQuantizer::quantize_bucket_stream`]) — but dispatches each
//!   section's buckets to the worker pool the moment backward reports
//!   the section complete, overlapping quantize+encode with the
//!   remaining backward compute. Segments concatenate in ascending
//!   bucket order behind one wire header, so the assembled message is
//!   byte-identical to [`super::collective::GradCodec::encode_into`]'s parallel path
//!   (`threads != 1`) — same wire bytes, same decoded means, same
//!   trained parameters, at every thread count. The exchange itself
//!   still moves that one flat message, which is what keeps ring/hier
//!   per-hop requantization chains (and their RNG draws) untouched.
//! * Closed-form overlapped time models — [`overlap_round_time`] is the
//!   serial-link pipeline recurrence `end_i = max(end_{i-1}, ready_i) +
//!   comm_i` over sections in send (readiness) order, plus the exposed
//!   non-overlappable tail (the mean broadcast). Per-topology wrappers
//!   ([`ps_overlap_time`], [`ring_overlap_time`], [`hier_overlap_time`],
//!   [`sharded_overlap_time`]) extend the flat `ps`/`ring`/`hier`/
//!   `sharded_time` models: with one section ready at time zero each
//!   degenerates to its flat model exactly, and with real section sizes
//!   the comm stays hidden behind compute until the tail.
//!
//! # Streaming mode (`--stream-sections`)
//!
//! [`OverlapEncoder::encode_streamed`] pushes each section's encoded
//! message into the collective the moment it is staged, instead of (only)
//! assembling one flat message. On the wire a streamed section is a
//! topology-agnostic **section frame** — the versioned
//! [`super::shard`] frame with `kind = `[`FrameKind::Section`]
//! [`super::shard::FrameKind::Section`], whose u16 slot carries the
//! *section index* and whose payload is:
//!
//! ```text
//! magic u32 | version u8 | kind u8 (=2) | section u16 | sender u16 |
//! round u64 | payload_len u32 | payload:
//!   ready_stamp  f64 LE   sim seconds since the round's backward began
//!   message      [u8]     one standalone codec message holding the
//!                         section's elements (or a bucket-aligned slice
//!                         of it: shard / ring-chunk intersections)
//! ```
//!
//! Sections hit the wire in *readiness order* — descending section index,
//! because backward produces gradients in reverse layer order — and the
//! in-band stamp is what lets the receiving coordinator replay the
//! pipeline recurrence `start_i = max(ready_i, link_free)` with exact
//! per-frame byte accounting (the `*_streamed_time` models below are the
//! same recurrence in closed form). PS and sharded-PS accumulate section
//! frames in worker order per section, so their means stay bit-identical
//! to the flat overlap path; hier streams hop-0 chunk slices up the
//! intra-group ring (and whole sections up the leader star when groups
//! are singletons), reassembling flat chunk messages at the receiver
//! ([`crate::codec::concat_messages_into`]), so it is bit-identical too.
//!
//! **Ring equivalence contract.** The streamed ring runs one
//! reduce-scatter/all-gather per section with one requantization-EF site
//! per (hop, section); its chunk grid differs from the flat ring's, so
//! streamed ring bytes *cannot* be bit-identical to the flat exchange.
//! The contract is instead: streamed ≡ serial replay of the same section
//! schedule, at any thread count — the wire bytes are a pure function of
//! the (deterministic, descending) section schedule, independent of
//! thread count, pool mode, and the readiness stamps. Tests drive the
//! same schedule through serial (`threads = 1`) and parallel encoders and
//! assert identical means and parameters.
//!
//! Serial codecs (`threads == 1`) overlap too: the encoder's per-bucket
//! RNG streams are start-anywhere (`Rng::stream(round_key, bucket)`), so
//! the driver thread simply encodes each staged section inline as
//! backward reports it. Serial and parallel overlap emit identical
//! bytes; they differ from the *legacy* serial flat encoder (one RNG
//! advanced across buckets), which cannot start mid-gradient — the same
//! split that already distinguishes `GradCodec`'s serial and parallel
//! paths.

use std::ops::Range;

use super::collective::{PoolMode, WireSpec};
use super::link::{Link, LinkMap};
use crate::codec::{self, BucketEncoder, Packing};
use crate::error::{Error, Result};
use crate::quant::bucket::BucketQuantizer;
use crate::quant::pool::PoolHandle;
use crate::quant::{self, QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

// --------------------------------------------------------------------
// Closed-form overlapped time models
// --------------------------------------------------------------------

/// Critical path of a section-pipelined exchange over one serial link:
/// section `i` (in send order — the order backward finishes them)
/// becomes ready at `ready_at[i]` and occupies the link for
/// `comm_s[i]`, so `end_i = max(end_{i-1}, ready_at[i]) + comm_s[i]`;
/// the non-overlappable tail (the assembled-mean broadcast) lands after
/// the last section. Comm stays hidden behind compute until the tail:
/// the result is `max(total compute, total comm)` when one side
/// dominates, and never exceeds `compute + comm + tail`.
pub fn overlap_round_time(ready_at: &[f64], comm_s: &[f64], tail_s: f64) -> f64 {
    assert_eq!(ready_at.len(), comm_s.len(), "one comm term per section");
    let mut end = 0.0f64;
    for (&r, &c) in ready_at.iter().zip(comm_s) {
        end = end.max(r) + c;
    }
    end + tail_s
}

/// Overlapped parameter-server round: per-section uplinks pipeline
/// behind compute, the FP mean broadcast is the exposed tail. With one
/// section ready at 0 this is exactly `ring::ps_time`.
pub fn ps_overlap_time(
    link: &Link,
    ready_at: &[f64],
    up_bytes: &[usize],
    down_bytes: usize,
) -> f64 {
    let comm: Vec<f64> = up_bytes.iter().map(|&b| link.transfer_time(b)).collect();
    overlap_round_time(ready_at, &comm, link.transfer_time(down_bytes))
}

/// Overlapped ring round: each section runs its own all-reduce as soon
/// as it is ready; there is no broadcast tail (the all-gather is part of
/// each section's collective). One section at 0 ≡ `ring::allreduce_time`.
pub fn ring_overlap_time(
    link: &Link,
    n: usize,
    ready_at: &[f64],
    section_bytes: &[usize],
) -> f64 {
    let comm: Vec<f64> = section_bytes
        .iter()
        .map(|&b| super::ring::allreduce_time(link, n, b))
        .collect();
    overlap_round_time(ready_at, &comm, 0.0)
}

/// Overlapped hierarchical round: each section's intra reduce-scatter +
/// gather and leader uplink pipeline behind compute; the FP mean
/// multicasts (inter star + intra group) are the exposed tail. One
/// section at 0 ≡ `hier::hier_time`.
pub fn hier_overlap_time(
    links: &LinkMap,
    l: usize,
    groups: usize,
    ready_at: &[f64],
    section_bytes: &[usize],
    fp_bytes: usize,
) -> f64 {
    assert!(l > 0 && groups > 0 && l % groups == 0);
    let m = l / groups;
    if l == 1 {
        return 0.0;
    }
    let up = |q: usize| {
        let mut t = 0.0;
        if m > 1 {
            // m−1 reduce-scatter hops + 1 gather, one q/m chunk each
            let chunk = q as f64 / m as f64;
            t += m as f64 * (links.intra.latency_s + chunk * 8.0 / links.intra.bandwidth_bps);
        }
        if groups > 1 {
            t += links.inter.transfer_time(q);
        }
        t
    };
    let comm: Vec<f64> = section_bytes.iter().map(|&b| up(b)).collect();
    let mut tail = 0.0;
    if m > 1 {
        tail += links.intra.transfer_time(fp_bytes);
    }
    if groups > 1 {
        tail += links.inter.transfer_time(fp_bytes);
    }
    overlap_round_time(ready_at, &comm, tail)
}

/// Overlapped sharded-PS round: per-section uploads stripe across the
/// `S` shards behind compute; the sharded FP downlink is the exposed
/// tail. One section at 0 ≡ `shard::sharded_time`.
pub fn sharded_overlap_time(
    link: &Link,
    shards: usize,
    ready_at: &[f64],
    up_bytes: &[usize],
    down_bytes: usize,
) -> f64 {
    assert!(shards > 0);
    let comm: Vec<f64> = up_bytes
        .iter()
        .map(|&b| link.latency_s + (b as f64 / shards as f64) * 8.0 / link.bandwidth_bps)
        .collect();
    let tail = link.latency_s + (down_bytes as f64 / shards as f64) * 8.0 / link.bandwidth_bps;
    overlap_round_time(ready_at, &comm, tail)
}

// --------------------------------------------------------------------
// Closed-form streamed time models
// --------------------------------------------------------------------
//
// The `*_streamed_time` models are the measured counterpart of the
// `*_overlap_time` family: they take the *actual per-section frame
// bytes* the streaming exchange puts on the wire (section frame header +
// readiness stamp + the section's codec message, in send order) and
// replay the exact recurrence the coordinator computes from the in-band
// stamps, so simulator and model agree to < 1% by construction.

/// Streamed parameter-server round: every worker's section frames
/// pipeline behind compute on its uplink (`end_i = max(end_{i-1},
/// ready_i) + transfer(frame_i)`, sections in send order), the mean
/// broadcast is the exposed tail. `ready_at`/`frame_bytes` are per
/// section in send (descending-index) order.
pub fn ps_streamed_time(
    link: &Link,
    ready_at: &[f64],
    frame_bytes: &[usize],
    down_bytes: usize,
) -> f64 {
    let comm: Vec<f64> = frame_bytes.iter().map(|&b| link.transfer_time(b)).collect();
    overlap_round_time(ready_at, &comm, link.transfer_time(down_bytes))
}

/// Streamed sharded-PS round: shard `s` receives each worker's
/// per-section chunk frames (`frame_bytes[s]`, send order) on its own
/// star, then broadcasts its mean frame (`down_bytes[s]`); the round
/// waits for the slowest shard.
pub fn sharded_streamed_time(
    link: &Link,
    ready_at: &[f64],
    frame_bytes: &[Vec<usize>],
    down_bytes: &[usize],
) -> f64 {
    assert_eq!(frame_bytes.len(), down_bytes.len(), "one downlink per shard");
    frame_bytes
        .iter()
        .zip(down_bytes)
        .map(|(fb, &db)| {
            let comm: Vec<f64> = fb.iter().map(|&b| link.transfer_time(b)).collect();
            overlap_round_time(ready_at, &comm, link.transfer_time(db))
        })
        .fold(0.0, f64::max)
}

/// Streamed hierarchical round. With member groups (`m = l/groups > 1`)
/// the readiness-gated leg is hop 0 of the intra reduce-scatter: each
/// worker streams per-section slices of its own chunk (`frame_bytes`,
/// send order), then the remaining `m − 2` hops + gather ride flat chunk
/// messages (`≈ q_bytes/m` each), the leader star moves `q_bytes` up and
/// `fp_bytes` down, and the groups multicast `fp_bytes`. With singleton
/// groups (`m == 1`) the leader star itself is the streamed leg.
pub fn hier_streamed_time(
    links: &LinkMap,
    l: usize,
    groups: usize,
    ready_at: &[f64],
    frame_bytes: &[usize],
    q_bytes: usize,
    fp_bytes: usize,
) -> f64 {
    assert!(l > 0 && groups > 0 && l % groups == 0);
    let m = l / groups;
    if l == 1 {
        return 0.0;
    }
    let leg = if m > 1 { &links.intra } else { &links.inter };
    let comm: Vec<f64> = frame_bytes.iter().map(|&b| leg.transfer_time(b)).collect();
    let mut t = overlap_round_time(ready_at, &comm, 0.0);
    if m > 1 {
        // m−2 remaining reduce-scatter hops + the gather, one chunk each
        let chunk = q_bytes as f64 / m as f64;
        t += (m - 1) as f64
            * (links.intra.latency_s + chunk * 8.0 / links.intra.bandwidth_bps);
        if groups > 1 {
            t += links.inter.transfer_time(q_bytes);
        }
    }
    if groups > 1 {
        t += links.inter.transfer_time(fp_bytes);
    }
    if m > 1 {
        t += links.intra.transfer_time(fp_bytes);
    }
    t
}

/// Streamed ring round: one reduce-scatter/all-gather per section, run
/// in send order, each gated on its readiness stamp
/// (`section_bytes` are the per-section encoded wire shares).
pub fn ring_streamed_time(
    link: &Link,
    n: usize,
    ready_at: &[f64],
    section_bytes: &[usize],
) -> f64 {
    ring_overlap_time(link, n, ready_at, section_bytes)
}

// --------------------------------------------------------------------
// Section bucket map
// --------------------------------------------------------------------

/// One model section of the overlap map: a contiguous run of whole
/// buckets (`buckets` are global bucket-grid indices, `elems` the
/// element range those buckets cover, clipped to the gradient length).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Section {
    pub elems: Range<usize>,
    pub buckets: Range<usize>,
}

/// The model-section bucket map: `sections` contiguous groups of layers,
/// balanced to within one layer, cut on the codec's bucket grid so every
/// bucket belongs to exactly one section.
#[derive(Debug, Clone)]
pub struct SectionMap {
    sections: Vec<Section>,
    bucket_size: usize,
    total: usize,
}

impl SectionMap {
    /// Build the map from a backend's layer spans (which must tile
    /// `0..param_count` contiguously). `sections` must be in
    /// `1..=layers`: zero sections is meaningless and more sections than
    /// layers would leave sections without a completion event.
    pub fn new(
        layer_spans: &[Range<usize>],
        sections: usize,
        bucket_size: usize,
    ) -> Result<SectionMap> {
        assert!(bucket_size > 0, "bucket_size is validated upstream");
        let layers = layer_spans.len();
        if layers == 0 {
            return Err(Error::InvalidArg("model reports no layer spans".into()));
        }
        let mut covered = 0usize;
        for (i, s) in layer_spans.iter().enumerate() {
            if s.start != covered || s.end < s.start {
                return Err(Error::InvalidArg(format!(
                    "layer spans must tile the parameter vector contiguously; \
                     span {i} is {s:?} after {covered} covered elements"
                )));
            }
            covered = s.end;
        }
        if sections == 0 {
            return Err(Error::InvalidArg(
                "sections must be at least 1 (got 0)".into(),
            ));
        }
        if sections > layers {
            return Err(Error::InvalidArg(format!(
                "sections ({sections}) exceeds the model's layer count ({layers}); \
                 every overlap section needs at least one layer — reduce sections"
            )));
        }
        let total = covered;
        let d = bucket_size;
        let nb = total.div_ceil(d);
        let boundary = |i: usize| {
            if i == sections {
                total
            } else {
                layer_spans[layers * i / sections].start
            }
        };
        // A bucket straddling a section boundary is owned by the lower
        // section (backward completes high offsets first, so the bucket
        // is only whole once the lower section's layers are done).
        let bucket_cut = |i: usize| {
            if i == sections {
                nb
            } else {
                boundary(i).div_ceil(d).min(nb)
            }
        };
        let mut out = Vec::with_capacity(sections);
        for i in 0..sections {
            let (b0, b1) = (bucket_cut(i), bucket_cut(i + 1));
            out.push(Section {
                elems: (b0 * d).min(total)..(b1 * d).min(total),
                buckets: b0..b1,
            });
        }
        Ok(SectionMap { sections: out, bucket_size: d, total })
    }

    pub fn sections(&self) -> &[Section] {
        &self.sections
    }

    /// Deterministic per-section readiness schedule for the streaming
    /// exchange, indexed by section id: backward produces elements in
    /// reverse order at `rate` elements per simulated second, so section
    /// `i` is complete — every element at or above its first owned
    /// element produced — after `(total − elems[i].start) / rate`
    /// seconds. Strictly decreasing in `i` while sections are non-empty,
    /// matching the descending send order.
    pub fn ready_schedule(&self, rate_elems_per_s: f64) -> Vec<f64> {
        assert!(
            rate_elems_per_s.is_finite() && rate_elems_per_s > 0.0,
            "backward rate must be positive"
        );
        self.sections
            .iter()
            .map(|s| (self.total - s.elems.start) as f64 / rate_elems_per_s)
            .collect()
    }

    pub fn num_sections(&self) -> usize {
        self.sections.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn bucket_size(&self) -> usize {
        self.bucket_size
    }
}

// --------------------------------------------------------------------
// The overlap driver
// --------------------------------------------------------------------

/// Per-section staging + encode arenas, reused across rounds (the
/// steady-state overlap path allocates nothing per section).
#[derive(Default)]
struct SectionArena {
    /// Staged gradient slice (compensated `g + m` under error feedback).
    gbuf: Vec<f32>,
    /// This section's encoded payload segment.
    seg: Vec<u8>,
    clip: Vec<f32>,
    qb: QuantizedBucket,
}

/// Default simulated backward rate (elements per simulated second) the
/// trainer feeds [`SectionMap::ready_schedule`] when streaming: the
/// stamp source for the section frames' readiness times. The value only
/// shapes the simulated compute/comm balance — correctness (bit
/// identity, schedule determinism) is independent of it.
pub const SIM_BACKWARD_RATE: f64 = 25.0e6;

/// The overlap driver: encodes sections on the worker pool (or inline on
/// the driver thread for serial specs) while backward produces the rest
/// of the gradient, then assembles the one flat wire message the
/// topology exchange expects — and, in streaming mode, pushes every
/// section's standalone message into the collective the moment its
/// encode completes.
pub struct OverlapEncoder {
    map: SectionMap,
    bucketq: BucketQuantizer,
    quantizer: Box<dyn Quantizer>,
    scheme: String,
    packing: Packing,
    levels: usize,
    /// `Some` = pooled section tasks (default); `None` = the legacy
    /// scoped-thread baseline (`--pool false`), one spawn per section.
    pool: Option<PoolHandle>,
    /// `threads == 1`: encode staged sections inline on the driver
    /// thread — same per-bucket RNG streams, same bytes, no spawns.
    serial: bool,
    /// Per-bucket bit widths for the current round
    /// ([`set_widths`](Self::set_widths)) — the byte-budget allocator's
    /// table. Empty/off ⇒ every bucket encodes at the scheme's fixed
    /// `levels` and the wire bytes are bit-identical to the pre-budget
    /// encoder.
    widths: Vec<u8>,
    widths_on: bool,
    /// Quantizer bank indexed by `width - 2`, lazily grown to the
    /// largest width any installed table requests. Only parameterizable
    /// families (`orq-S`/`qsgd-S`/`linear-S`) can populate it.
    bank: Vec<Box<dyn Quantizer>>,
    arenas: Vec<SectionArena>,
    /// Per-section standalone message buffers (streaming mode), reused
    /// across rounds.
    msgs: Vec<Vec<u8>>,
    section_bytes: Vec<usize>,
    /// Trace recorder (from the wire spec) + the track staging instants
    /// land on — [`set_track`](Self::set_track) points it at the owning
    /// worker's row. Instants rather than spans: staging happens on the
    /// backward thread inside the trainer's own phase spans.
    recorder: crate::obs::TraceRecorder,
    track: crate::obs::Track,
}

impl OverlapEncoder {
    /// Build the driver for a quantizing spec. Rejects FP (no bucket
    /// grid to pipeline). Serial specs (`threads == 1`) encode staged
    /// sections inline on the driver thread: the per-bucket RNG streams
    /// are start-anywhere, so serial overlap emits the same bytes as the
    /// parallel overlap/flat-parallel encode (*not* the legacy serial
    /// flat encoder, whose single RNG stream cannot start mid-gradient).
    pub fn new(spec: &WireSpec, map: SectionMap) -> Result<OverlapEncoder> {
        let quantizer = quant::from_name(&spec.method)?;
        let levels = quantizer.num_levels();
        if levels == 0 {
            return Err(Error::InvalidArg(
                "overlap needs a quantizing method; fp gradients have no bucket \
                 grid to pipeline (disable overlap or pick a quantized scheme)"
                    .into(),
            ));
        }
        if map.bucket_size != spec.bucket_size {
            return Err(Error::InvalidArg(format!(
                "section map bucket size ({}) does not match the wire spec ({})",
                map.bucket_size, spec.bucket_size
            )));
        }
        let bucketq = match spec.clip_factor {
            Some(c) => BucketQuantizer::with_clip(spec.bucket_size, c),
            None => BucketQuantizer::new(spec.bucket_size),
        };
        let serial = spec.threads == 1;
        let pool = if serial {
            None
        } else {
            match &spec.pool {
                PoolMode::Pooled => Some(PoolHandle::new(spec.threads)),
                PoolMode::Shared(h) => Some(h.clone()),
                PoolMode::Scoped => None,
            }
        };
        Ok(OverlapEncoder {
            map,
            bucketq,
            quantizer,
            scheme: spec.method.clone(),
            packing: spec.packing,
            levels,
            pool,
            serial,
            widths: Vec::new(),
            widths_on: false,
            bank: Vec::new(),
            arenas: Vec::new(),
            msgs: Vec::new(),
            section_bytes: Vec::new(),
            recorder: spec.recorder.clone(),
            track: crate::obs::Track::Driver,
        })
    }

    /// Point the staging instants at the owning worker's trace row.
    pub fn set_track(&mut self, track: crate::obs::Track) {
        self.track = track;
    }

    /// Install this round's per-bucket width table (the byte-budget
    /// allocator's output, [`crate::quant::budget::allocate_widths`]) —
    /// or `None` to restore the fixed-width encode. The table must hold
    /// one entry per bucket of the full gradient; every entry picks that
    /// bucket's level count, and the assembled flat message (and each
    /// streamed section message) carries the table in-band exactly like
    /// [`super::collective::GradCodec`]'s budgeted path, so downstream
    /// hops decode the widths from the frame rather than assuming them.
    pub fn set_widths(&mut self, widths: Option<&[u8]>) -> Result<()> {
        let Some(table) = widths else {
            self.widths_on = false;
            return Ok(());
        };
        let nb = self.map.total.div_ceil(self.map.bucket_size.max(1));
        if table.len() != nb || nb == 0 {
            if nb == 0 {
                // Nothing to encode; the plain path already handles it.
                self.widths_on = false;
                return Ok(());
            }
            return Err(Error::Comm(format!(
                "width table has {} entries but the section map covers {nb} buckets",
                table.len()
            )));
        }
        let s_max = table.iter().copied().max().unwrap_or(2).max(2) as usize;
        let (family, _) = crate::quant::budget::parse_family(&self.scheme).ok_or_else(|| {
            Error::Config(format!(
                "per-bucket width tables need a parameterizable scheme \
                 (orq-S / qsgd-S / linear-S), not '{}'",
                self.scheme
            ))
        })?;
        while self.bank.len() + 2 <= s_max {
            let s = self.bank.len() + 2;
            self.bank.push(quant::from_name(&format!("{family}-{s}"))?);
        }
        self.widths.clear();
        self.widths.extend_from_slice(table);
        self.widths_on = true;
        Ok(())
    }

    pub fn map(&self) -> &SectionMap {
        &self.map
    }

    /// Encoded payload bytes of each section from the last round (the
    /// per-section wire share the overlapped time models take; the
    /// header is common). Empty before the first round.
    pub fn section_bytes(&self) -> &[usize] {
        &self.section_bytes
    }

    /// Drive one overlapped backward+encode: `backward` runs the model's
    /// sectioned backward ([`crate::model::Backend::loss_grad_sections`])
    /// against the provided readiness callback, and every section is
    /// quantized+encoded concurrently with the remaining backward
    /// compute as soon as its first owned element is behind the reported
    /// frontier. Returns the loss; `out` receives the assembled wire
    /// message, byte-identical to
    /// [`super::collective::GradCodec::encode_into`]'s parallel
    /// path on the full gradient (one round key drawn from `rng`, global
    /// per-bucket streams, segments in ascending bucket order).
    ///
    /// `memory` is the error-feedback residual: when present, sections
    /// stage `g[sec] + m[sec]` — elementwise identical to
    /// [`ErrorFeedback::compensate`](crate::quant::error_feedback::ErrorFeedback)
    /// on the full gradient, so EF wire bytes match the flat EF path
    /// bit for bit. The caller owns the residual update (decode the
    /// assembled message, then `compensate` + `update_residual`).
    pub fn encode_overlapped(
        &mut self,
        memory: Option<&[f32]>,
        rng: &mut Rng,
        out: &mut Vec<u8>,
        backward: impl FnOnce(&mut dyn FnMut(usize, &[f32])) -> f32,
    ) -> f32 {
        let n = self.map.total;
        let nsec = self.map.sections.len();
        if let Some(m) = memory {
            assert_eq!(m.len(), n, "EF residual length");
        }
        // Exactly the parallel codec's RNG discipline: one key per round.
        let round_key = rng.next_u64();
        let enc = BucketEncoder::new(self.levels, self.packing);
        while self.arenas.len() < nsec {
            self.arenas.push(SectionArena::default());
        }
        let arenas = &mut self.arenas[..nsec];
        let map = &self.map;
        let bq = &self.bucketq;
        let q = self.quantizer.as_ref();
        let packing = self.packing;
        let wt: Option<(&[u8], &[Box<dyn Quantizer>])> = if self.widths_on {
            Some((&self.widths[..], &self.bank[..]))
        } else {
            None
        };
        let (rec, track) = (self.recorder.clone(), self.track);
        let fine = rec.is_fine();
        let mut loss = 0.0f32;
        if self.serial {
            // Start-anywhere serial overlap: encode each staged section
            // inline on the driver thread — per-bucket RNG streams make
            // the bytes identical to the pooled dispatch.
            let mut next = nsec;
            let mut on_ready = |frontier: usize, g: &[f32]| {
                debug_assert_eq!(g.len(), n, "gradient length");
                while next > 0 && map.sections[next - 1].elems.start >= frontier {
                    next -= 1;
                    let s = &map.sections[next];
                    let a = &mut arenas[next];
                    stage(a, g, memory, &s.elems);
                    if fine {
                        rec.instant(track, "section_staged");
                    }
                    encode_section(
                        bq,
                        q,
                        wt,
                        round_key,
                        s.buckets.clone(),
                        s.elems.start,
                        enc,
                        packing,
                        a,
                    );
                }
            };
            loss = backward(&mut on_ready);
            debug_assert_eq!(next, 0, "backward must report frontier 0");
        } else {
            match &self.pool {
                Some(pool) => pool
                    .scope(|sc| {
                        let mut slots: Vec<Option<&mut SectionArena>> =
                            arenas.iter_mut().map(Some).collect();
                        // Sections ready so far form a suffix [next, nsec).
                        let mut next = nsec;
                        let mut on_ready = |frontier: usize, g: &[f32]| {
                            debug_assert_eq!(g.len(), n, "gradient length");
                            while next > 0 && map.sections[next - 1].elems.start >= frontier {
                                next -= 1;
                                let s = &map.sections[next];
                                let a = slots[next].take().expect("section dispatched once");
                                stage(a, g, memory, &s.elems);
                                if fine {
                                    rec.instant(track, "section_staged");
                                }
                                let (buckets, e0) = (s.buckets.clone(), s.elems.start);
                                sc.spawn(move || {
                                    encode_section(
                                        bq, q, wt, round_key, buckets, e0, enc, packing, a,
                                    )
                                });
                            }
                        };
                        loss = backward(&mut on_ready);
                        debug_assert_eq!(next, 0, "backward must report frontier 0");
                    })
                    .unwrap_or_else(|e| panic!("overlapped encode failed: {e}")),
                None => std::thread::scope(|scope| {
                    let mut slots: Vec<Option<&mut SectionArena>> =
                        arenas.iter_mut().map(Some).collect();
                    let mut next = nsec;
                    let mut on_ready = |frontier: usize, g: &[f32]| {
                        debug_assert_eq!(g.len(), n, "gradient length");
                        while next > 0 && map.sections[next - 1].elems.start >= frontier {
                            next -= 1;
                            let s = &map.sections[next];
                            let a = slots[next].take().expect("section dispatched once");
                            stage(a, g, memory, &s.elems);
                            if fine {
                                rec.instant(track, "section_staged");
                            }
                            let (buckets, e0) = (s.buckets.clone(), s.elems.start);
                            scope.spawn(move || {
                                encode_section(bq, q, wt, round_key, buckets, e0, enc, packing, a)
                            });
                        }
                    };
                    loss = backward(&mut on_ready);
                    debug_assert_eq!(next, 0, "backward must report frontier 0");
                }),
            }
        }
        // Assemble: one header, then every section's segment in ascending
        // bucket order — the exact flat parallel wire layout. With a
        // width table armed the header carries the table in-band
        // (FLAG_WIDTHS), matching `GradCodec`'s budgeted encode.
        out.clear();
        if self.widths_on {
            codec::encode_quantized_header_widths_into(
                &self.widths,
                &self.scheme,
                self.packing,
                n,
                self.bucketq.bucket_size,
                out,
            );
        } else {
            codec::encode_quantized_header_into(
                self.levels,
                &self.scheme,
                self.packing,
                n,
                self.bucketq.bucket_size,
                out,
            );
        }
        self.section_bytes.clear();
        for a in &self.arenas[..nsec] {
            self.section_bytes.push(a.seg.len());
            out.extend_from_slice(&a.seg);
        }
        loss
    }

    /// Drive one *streamed* backward+encode: like
    /// [`encode_overlapped`](Self::encode_overlapped), but every
    /// section's encoded payload is additionally framed as a standalone
    /// codec message and handed to `sink(section, message, ready_s)` in
    /// strict readiness order (descending section index) the moment its
    /// encode completes — the trainer's sink pushes it into the
    /// collective as a section frame
    /// ([`WorkerExchange::push_section`](super::collective::WorkerExchange::push_section)).
    /// `ready_at[i]` is section `i`'s deterministic readiness stamp
    /// ([`SectionMap::ready_schedule`]); it rides in-band so the
    /// coordinator can replay the pipeline recurrence. The flat message
    /// is still assembled into `out` (the caller's error-feedback settle
    /// decodes its own bytes), and the per-section messages concatenate
    /// back to exactly those flat bytes
    /// ([`crate::codec::concat_messages_into`]).
    ///
    /// The sink bytes are a pure function of the section schedule and
    /// the RNG discipline — identical across thread counts, pool modes
    /// and stamp values. A sink error stops further pushes and is
    /// returned after the round's encodes drain.
    #[allow(clippy::too_many_arguments)]
    pub fn encode_streamed(
        &mut self,
        memory: Option<&[f32]>,
        rng: &mut Rng,
        out: &mut Vec<u8>,
        ready_at: &[f64],
        sink: &mut dyn FnMut(usize, &[u8], f64) -> Result<()>,
        backward: impl FnOnce(&mut dyn FnMut(usize, &[f32])) -> f32,
    ) -> Result<f32> {
        let n = self.map.total;
        let nsec = self.map.sections.len();
        if ready_at.len() != nsec {
            return Err(Error::InvalidArg(format!(
                "ready schedule has {} entries for {nsec} sections",
                ready_at.len()
            )));
        }
        if let Some(m) = memory {
            assert_eq!(m.len(), n, "EF residual length");
        }
        let round_key = rng.next_u64();
        let enc = BucketEncoder::new(self.levels, self.packing);
        while self.arenas.len() < nsec {
            self.arenas.push(SectionArena::default());
        }
        while self.msgs.len() < nsec {
            self.msgs.push(Vec::new());
        }
        let arenas = &mut self.arenas[..nsec];
        let msgs = &mut self.msgs[..nsec];
        let map = &self.map;
        let bq = &self.bucketq;
        let q = self.quantizer.as_ref();
        let (levels, packing, d) = (self.levels, self.packing, self.bucketq.bucket_size);
        let scheme = self.scheme.as_str();
        let wt: Option<(&[u8], &[Box<dyn Quantizer>])> = if self.widths_on {
            Some((&self.widths[..], &self.bank[..]))
        } else {
            None
        };
        let (rec, track) = (self.recorder.clone(), self.track);
        let fine = rec.is_fine();
        let mut sink_err: Option<Error> = None;
        let mut loss = 0.0f32;
        if self.serial {
            // Inline start-anywhere encode: stage, encode and push each
            // section on the driver thread in readiness order.
            let mut next = nsec;
            let mut on_ready = |frontier: usize, g: &[f32]| {
                debug_assert_eq!(g.len(), n, "gradient length");
                while next > 0 && map.sections[next - 1].elems.start >= frontier {
                    next -= 1;
                    let s = &map.sections[next];
                    let a = &mut arenas[next];
                    stage(a, g, memory, &s.elems);
                    if fine {
                        rec.instant(track, "section_staged");
                    }
                    encode_section(
                        bq,
                        q,
                        wt,
                        round_key,
                        s.buckets.clone(),
                        s.elems.start,
                        enc,
                        packing,
                        a,
                    );
                    let m = &mut msgs[next];
                    m.clear();
                    // Each standalone section message carries its own
                    // sub-table slice (header `s` = sub-table max), so
                    // concatenation reproduces the flat budgeted bytes.
                    // Empty sections fall back to the uniform header —
                    // the format forbids width tables on zero elements.
                    match wt {
                        Some((table, _)) if !s.buckets.is_empty() => {
                            codec::encode_quantized_header_widths_into(
                                &table[s.buckets.clone()],
                                scheme,
                                packing,
                                s.elems.len(),
                                d,
                                m,
                            )
                        }
                        _ => codec::encode_quantized_header_into(
                            levels,
                            scheme,
                            packing,
                            s.elems.len(),
                            d,
                            m,
                        ),
                    }
                    m.extend_from_slice(&a.seg);
                    if sink_err.is_none() {
                        if fine {
                            rec.instant_sim(track, "section_push", ready_at[next]);
                        }
                        if let Err(e) = sink(next, m, ready_at[next]) {
                            sink_err = Some(e);
                        }
                    }
                }
            };
            loss = backward(&mut on_ready);
            debug_assert_eq!(next, 0, "backward must report frontier 0");
        } else {
            // Pooled/scoped dispatch with a completion channel: encode
            // tasks report back, the driver pushes completed sections in
            // strict descending order while backward keeps running, and
            // drains the rest after the join.
            let (tx, rx) = std::sync::mpsc::channel::<(usize, Vec<u8>)>();
            let mut pending: Vec<Option<Vec<u8>>> = (0..nsec).map(|_| None).collect();
            let mut next_sink = nsec;
            {
                let pending = &mut pending;
                let next_sink = &mut next_sink;
                let sink_err = &mut sink_err;
                match &self.pool {
                    Some(pool) => pool
                        .scope(|sc| {
                            let mut slots: Vec<Option<&mut SectionArena>> =
                                arenas.iter_mut().map(Some).collect();
                            let mut next = nsec;
                            let mut on_ready = |frontier: usize, g: &[f32]| {
                                debug_assert_eq!(g.len(), n, "gradient length");
                                while next > 0 && map.sections[next - 1].elems.start >= frontier {
                                    next -= 1;
                                    let idx = next;
                                    let s = &map.sections[idx];
                                    let a = slots[idx].take().expect("section dispatched once");
                                    stage(a, g, memory, &s.elems);
                                    if fine {
                                        rec.instant(track, "section_staged");
                                    }
                                    let mut buf = std::mem::take(&mut msgs[idx]);
                                    let (buckets, e0, len) =
                                        (s.buckets.clone(), s.elems.start, s.elems.len());
                                    let tx = tx.clone();
                                    sc.spawn(move || {
                                        encode_section(
                                            bq,
                                            q,
                                            wt,
                                            round_key,
                                            buckets.clone(),
                                            e0,
                                            enc,
                                            packing,
                                            a,
                                        );
                                        buf.clear();
                                        match wt {
                                            Some((table, _)) if !buckets.is_empty() => {
                                                codec::encode_quantized_header_widths_into(
                                                    &table[buckets],
                                                    scheme,
                                                    packing,
                                                    len,
                                                    d,
                                                    &mut buf,
                                                )
                                            }
                                            _ => codec::encode_quantized_header_into(
                                                levels, scheme, packing, len, d, &mut buf,
                                            ),
                                        }
                                        buf.extend_from_slice(&a.seg);
                                        let _ = tx.send((idx, buf));
                                    });
                                    while let Ok((i, b)) = rx.try_recv() {
                                        pending[i] = Some(b);
                                    }
                                    while *next_sink > 0 {
                                        let i = *next_sink - 1;
                                        let Some(b) = pending[i].take() else { break };
                                        *next_sink = i;
                                        if sink_err.is_none() {
                                            if fine {
                                                rec.instant_sim(track, "section_push", ready_at[i]);
                                            }
                                            if let Err(e) = sink(i, &b, ready_at[i]) {
                                                *sink_err = Some(e);
                                            }
                                        }
                                        msgs[i] = b;
                                    }
                                }
                            };
                            loss = backward(&mut on_ready);
                            debug_assert_eq!(next, 0, "backward must report frontier 0");
                        })
                        .unwrap_or_else(|e| panic!("streamed encode failed: {e}")),
                    None => std::thread::scope(|scope| {
                        let mut slots: Vec<Option<&mut SectionArena>> =
                            arenas.iter_mut().map(Some).collect();
                        let mut next = nsec;
                        let mut on_ready = |frontier: usize, g: &[f32]| {
                            debug_assert_eq!(g.len(), n, "gradient length");
                            while next > 0 && map.sections[next - 1].elems.start >= frontier {
                                next -= 1;
                                let idx = next;
                                let s = &map.sections[idx];
                                let a = slots[idx].take().expect("section dispatched once");
                                stage(a, g, memory, &s.elems);
                                if fine {
                                    rec.instant(track, "section_staged");
                                }
                                let mut buf = std::mem::take(&mut msgs[idx]);
                                let (buckets, e0, len) =
                                    (s.buckets.clone(), s.elems.start, s.elems.len());
                                let tx = tx.clone();
                                scope.spawn(move || {
                                    encode_section(
                                        bq,
                                        q,
                                        wt,
                                        round_key,
                                        buckets.clone(),
                                        e0,
                                        enc,
                                        packing,
                                        a,
                                    );
                                    buf.clear();
                                    match wt {
                                        Some((table, _)) if !buckets.is_empty() => {
                                            codec::encode_quantized_header_widths_into(
                                                &table[buckets],
                                                scheme,
                                                packing,
                                                len,
                                                d,
                                                &mut buf,
                                            )
                                        }
                                        _ => codec::encode_quantized_header_into(
                                            levels, scheme, packing, len, d, &mut buf,
                                        ),
                                    }
                                    buf.extend_from_slice(&a.seg);
                                    let _ = tx.send((idx, buf));
                                });
                                while let Ok((i, b)) = rx.try_recv() {
                                    pending[i] = Some(b);
                                }
                                while *next_sink > 0 {
                                    let i = *next_sink - 1;
                                    let Some(b) = pending[i].take() else { break };
                                    *next_sink = i;
                                    if sink_err.is_none() {
                                        if fine {
                                            rec.instant_sim(track, "section_push", ready_at[i]);
                                        }
                                        if let Err(e) = sink(i, &b, ready_at[i]) {
                                            *sink_err = Some(e);
                                        }
                                    }
                                    msgs[i] = b;
                                }
                            }
                        };
                        loss = backward(&mut on_ready);
                        debug_assert_eq!(next, 0, "backward must report frontier 0");
                    }),
                }
            }
            // Every task has joined: drain the channel and push the
            // remaining sections in order.
            while let Ok((i, b)) = rx.try_recv() {
                pending[i] = Some(b);
            }
            while next_sink > 0 {
                let i = next_sink - 1;
                let b = pending[i].take().expect("all section encodes completed");
                next_sink = i;
                if sink_err.is_none() {
                    if fine {
                        rec.instant_sim(track, "section_push", ready_at[i]);
                    }
                    if let Err(e) = sink(i, &b, ready_at[i]) {
                        sink_err = Some(e);
                    }
                }
                msgs[i] = b;
            }
        }
        // Assemble the flat message (EF settle / self-decode path).
        out.clear();
        if self.widths_on {
            codec::encode_quantized_header_widths_into(
                &self.widths,
                &self.scheme,
                self.packing,
                n,
                self.bucketq.bucket_size,
                out,
            );
        } else {
            codec::encode_quantized_header_into(
                self.levels,
                &self.scheme,
                self.packing,
                n,
                self.bucketq.bucket_size,
                out,
            );
        }
        self.section_bytes.clear();
        for a in &self.arenas[..nsec] {
            self.section_bytes.push(a.seg.len());
            out.extend_from_slice(&a.seg);
        }
        match sink_err {
            Some(e) => Err(e),
            None => Ok(loss),
        }
    }
}

/// Copy a section's gradient slice (plus the EF residual, when present)
/// into its staging buffer on the backward thread — the encode task must
/// not borrow the live gradient.
fn stage(a: &mut SectionArena, g: &[f32], memory: Option<&[f32]>, elems: &Range<usize>) {
    a.gbuf.clear();
    match memory {
        Some(m) => a.gbuf.extend(
            g[elems.clone()]
                .iter()
                .zip(&m[elems.clone()])
                .map(|(x, r)| x + r),
        ),
        None => a.gbuf.extend_from_slice(&g[elems.clone()]),
    }
}

/// Quantize and serialize one section's run of buckets into its segment
/// buffer. `buckets` are global grid indices — the RNG stream of bucket
/// `bi` is `Rng::stream(round_key, bi)` exactly as in the flat parallel
/// encode, which is what makes the assembled bytes identical. `wt`
/// carries the round's per-bucket width table plus the quantizer bank
/// (indexed `width - 2`) when a byte budget is armed: each bucket then
/// quantizes at its own level count on the same per-bucket stream, so
/// budgeted bytes are thread-count invariant too.
#[allow(clippy::too_many_arguments)]
fn encode_section(
    bq: &BucketQuantizer,
    q: &dyn Quantizer,
    wt: Option<(&[u8], &[Box<dyn Quantizer>])>,
    round_key: u64,
    buckets: Range<usize>,
    elems_start: usize,
    enc: BucketEncoder,
    packing: Packing,
    a: &mut SectionArena,
) {
    a.seg.clear();
    let d = bq.bucket_size;
    for bi in buckets {
        let lo = bi * d - elems_start;
        let hi = (lo + d).min(a.gbuf.len());
        match wt {
            Some((table, bank)) => {
                let w = table[bi] as usize;
                let qw = bank[w - 2].as_ref();
                bq.quantize_bucket_stream(&a.gbuf[lo..hi], bi, qw, round_key, &mut a.clip, &mut a.qb);
                debug_assert_eq!(a.qb.levels.len(), w, "bank quantizer width");
                BucketEncoder::new(w, packing).encode_bucket_into(&a.qb, &mut a.seg);
            }
            None => {
                bq.quantize_bucket_stream(&a.gbuf[lo..hi], bi, q, round_key, &mut a.clip, &mut a.qb);
                enc.encode_bucket_into(&a.qb, &mut a.seg);
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::GradCodec;
    use crate::quant::bucket::QuantizedGrad;

    fn spans(sizes: &[usize]) -> Vec<Range<usize>> {
        let mut out = Vec::new();
        let mut off = 0;
        for &s in sizes {
            out.push(off..off + s);
            off += s;
        }
        out
    }

    #[test]
    fn section_map_tiles_bucket_grid_and_assigns_straddlers_low() {
        // layers of 100/60/40 elements on a 64 grid: boundaries at 100
        // and 160 both straddle buckets.
        let sp = spans(&[100, 60, 40]);
        let m = SectionMap::new(&sp, 3, 64).unwrap();
        let s = m.sections();
        assert_eq!(s.len(), 3);
        // bucket cuts at ceil(100/64)=2 and ceil(160/64)=3; nb=ceil(200/64)=4
        assert_eq!(s[0].buckets, 0..2);
        assert_eq!(s[1].buckets, 2..3);
        assert_eq!(s[2].buckets, 3..4);
        assert_eq!(s[0].elems, 0..128);
        assert_eq!(s[1].elems, 128..192);
        assert_eq!(s[2].elems, 192..200);
        // the map tiles: buckets and elems are contiguous and complete
        assert_eq!(s.iter().map(|x| x.buckets.len()).sum::<usize>(), 4);
        assert_eq!(s.last().unwrap().elems.end, 200);
        // every owned element starts at or after its section's layer
        // boundary — the readiness threshold is conservative
        assert!(s[1].elems.start >= 100 && s[2].elems.start >= 160);
    }

    #[test]
    fn section_map_tolerates_sections_swallowed_by_one_bucket() {
        // three 10-element layers inside one 64 bucket: middle sections
        // own no buckets; the lowest owns the lot.
        let sp = spans(&[10, 10, 10]);
        let m = SectionMap::new(&sp, 3, 64).unwrap();
        let s = m.sections();
        assert_eq!(s[0].buckets, 0..1);
        assert!(s[1].buckets.is_empty() && s[2].buckets.is_empty());
        assert_eq!(s[0].elems, 0..30);
    }

    #[test]
    fn section_map_rejects_bad_shapes() {
        let sp = spans(&[100, 100]);
        assert!(SectionMap::new(&sp, 0, 64).is_err(), "sections = 0");
        assert!(SectionMap::new(&sp, 3, 64).is_err(), "sections > layers");
        assert!(SectionMap::new(&[], 1, 64).is_err(), "no layers");
        // non-tiling spans
        assert!(SectionMap::new(&[0..10, 20..30], 1, 64).is_err());
        // degenerate single section is fine
        assert!(SectionMap::new(&sp, 1, 64).is_ok());
    }

    /// The assembled overlapped message must be byte-identical to the
    /// flat parallel encode, with identical RNG consumption — plain and
    /// with an error-feedback residual staged section-wise.
    #[test]
    fn overlapped_encode_bit_identical_to_flat_parallel_encode() {
        let sp = spans(&[700, 500, 300, 100]);
        let n = 1600;
        let g: Vec<f32> = (0..n).map(|i| ((i * 31) % 113) as f32 / 113.0 - 0.5).collect();
        let mem: Vec<f32> = (0..n).map(|i| ((i * 7) % 29) as f32 / 290.0).collect();
        for threads in [2usize, 4] {
            for memory in [None, Some(&mem[..])] {
                let spec = WireSpec::new("orq-5", 64).with_threads(threads);
                let map = SectionMap::new(&sp, 3, 64).unwrap();
                let mut ov = OverlapEncoder::new(&spec, map).unwrap();
                let mut rng_a = Rng::stream(9, 1);
                let mut overlapped = Vec::new();
                // a synthetic reverse-layer backward: report frontiers in
                // descending layer order, as the MLP backward does
                let loss = ov.encode_overlapped(memory, &mut rng_a, &mut overlapped, |cb| {
                    for l in (0..sp.len()).rev() {
                        cb(sp[l].start, &g);
                    }
                    1.5
                });
                assert_eq!(loss, 1.5);

                let mut gc = GradCodec::new(&spec).unwrap();
                let mut rng_b = Rng::stream(9, 1);
                let mut qg = QuantizedGrad::default();
                let mut flat = Vec::new();
                let signal: Vec<f32> = match memory {
                    Some(m) => g.iter().zip(m).map(|(a, b)| a + b).collect(),
                    None => g.clone(),
                };
                gc.encode_into(&signal, &mut rng_b, &mut qg, &mut flat);
                assert_eq!(
                    overlapped, flat,
                    "threads={threads} ef={}",
                    memory.is_some()
                );
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG draw parity");
                // per-section accounting covers the whole payload
                let header = flat.len() - ov.section_bytes().iter().sum::<usize>();
                assert!(header > 0 && header < 64, "header share {header}");
            }
        }
    }

    /// Scoped (pool-less) execution is the same bytes — the `--pool
    /// false` baseline must stay bit-identical.
    #[test]
    fn overlapped_encode_scoped_matches_pooled() {
        use crate::comm::collective::PoolMode;
        let sp = spans(&[600, 400, 200]);
        let g: Vec<f32> = (0..1200).map(|i| ((i * 13) % 97) as f32 / 97.0 - 0.5).collect();
        let drive = |spec: &WireSpec| {
            let map = SectionMap::new(&sp, 2, 128).unwrap();
            let mut ov = OverlapEncoder::new(spec, map).unwrap();
            let mut rng = Rng::stream(4, 2);
            let mut msg = Vec::new();
            ov.encode_overlapped(None, &mut rng, &mut msg, |cb| {
                for l in (0..sp.len()).rev() {
                    cb(sp[l].start, &g);
                }
                0.0
            });
            msg
        };
        let pooled = drive(&WireSpec::new("terngrad", 128).with_threads(2));
        let scoped = drive(
            &WireSpec::new("terngrad", 128)
                .with_threads(2)
                .with_pool_mode(PoolMode::Scoped),
        );
        assert_eq!(pooled, scoped);
    }

    #[test]
    fn overlap_encoder_rejects_fp_and_mismatched_specs() {
        let sp = spans(&[128, 128]);
        let map = SectionMap::new(&sp, 2, 64).unwrap();
        assert!(OverlapEncoder::new(&WireSpec::new("fp", 64).with_threads(2), map.clone()).is_err());
        // serial specs are accepted: the start-anywhere encoder runs inline
        assert!(OverlapEncoder::new(&WireSpec::new("terngrad", 64), map.clone()).is_ok());
        // bucket-size mismatch between map and spec
        assert!(
            OverlapEncoder::new(&WireSpec::new("terngrad", 128).with_threads(2), map).is_err()
        );
    }

    /// Satellite contract: serial (`threads = 1`) overlap encodes staged
    /// sections inline and emits byte-identical wire bytes to the
    /// parallel overlap (and therefore to the flat parallel encode) —
    /// with and without an EF residual.
    #[test]
    fn serial_overlap_matches_parallel_bytes() {
        let sp = spans(&[500, 300, 200, 200]);
        let n = 1200;
        let g: Vec<f32> = (0..n).map(|i| ((i * 17) % 101) as f32 / 101.0 - 0.5).collect();
        let mem: Vec<f32> = (0..n).map(|i| ((i * 5) % 23) as f32 / 230.0).collect();
        for memory in [None, Some(&mem[..])] {
            let drive = |threads: usize| {
                let spec = WireSpec::new("orq-5", 64).with_threads(threads);
                let map = SectionMap::new(&sp, 3, 64).unwrap();
                let mut ov = OverlapEncoder::new(&spec, map).unwrap();
                let mut rng = Rng::stream(11, 3);
                let mut msg = Vec::new();
                ov.encode_overlapped(memory, &mut rng, &mut msg, |cb| {
                    for l in (0..sp.len()).rev() {
                        cb(sp[l].start, &g);
                    }
                    0.0
                });
                msg
            };
            let serial = drive(1);
            let parallel = drive(2);
            assert_eq!(serial, parallel, "ef={}", memory.is_some());
        }
    }

    /// Streamed encode pushes every section in strict descending order
    /// with its schedule stamp, the pushed standalone messages
    /// concatenate back to exactly the assembled flat message, and the
    /// sink bytes are identical across thread counts and pool modes.
    #[test]
    fn streamed_sink_order_stamps_and_concat() {
        use crate::comm::collective::PoolMode;
        let sp = spans(&[700, 500, 300, 100]);
        let n = 1600;
        let g: Vec<f32> = (0..n).map(|i| ((i * 31) % 113) as f32 / 113.0 - 0.5).collect();
        let drive = |spec: &WireSpec| {
            let map = SectionMap::new(&sp, 3, 64).unwrap();
            let ready = map.ready_schedule(1.0e6);
            let mut ov = OverlapEncoder::new(spec, map).unwrap();
            let mut rng = Rng::stream(21, 1);
            let mut flat = Vec::new();
            let mut pushed: Vec<(usize, Vec<u8>, f64)> = Vec::new();
            let loss = ov
                .encode_streamed(
                    None,
                    &mut rng,
                    &mut flat,
                    &ready,
                    &mut |sec, msg, r| {
                        pushed.push((sec, msg.to_vec(), r));
                        Ok(())
                    },
                    |cb| {
                        for l in (0..sp.len()).rev() {
                            cb(sp[l].start, &g);
                        }
                        2.5
                    },
                )
                .unwrap();
            assert_eq!(loss, 2.5);
            (flat, pushed, ready)
        };
        let (flat, pushed, ready) = drive(&WireSpec::new("orq-5", 64).with_threads(2));
        // strict descending section order, stamps straight from the schedule
        assert_eq!(pushed.len(), 3);
        for (k, (sec, _, r)) in pushed.iter().enumerate() {
            assert_eq!(*sec, 2 - k, "descending send order");
            assert_eq!(*r, ready[*sec], "schedule stamp rides with the push");
        }
        // ascending-order concat of the pushed messages = the flat bytes
        let ascending: Vec<&[u8]> = pushed.iter().rev().map(|(_, m, _)| m.as_slice()).collect();
        let mut back = Vec::new();
        codec::concat_messages_into(&ascending, &mut back).unwrap();
        assert_eq!(back, flat, "sections reassemble to the flat message");
        // identical sink bytes at every thread count and pool mode
        for spec in [
            WireSpec::new("orq-5", 64),
            WireSpec::new("orq-5", 64).with_threads(4),
            WireSpec::new("orq-5", 64).with_threads(2).with_pool_mode(PoolMode::Scoped),
        ] {
            let (f2, p2, _) = drive(&spec);
            assert_eq!(f2, flat, "flat bytes invariant (threads={})", spec.threads);
            assert_eq!(p2, pushed, "sink bytes invariant (threads={})", spec.threads);
        }
        // a lying schedule length is rejected
        let map = SectionMap::new(&sp, 3, 64).unwrap();
        let mut ov = OverlapEncoder::new(&WireSpec::new("orq-5", 64).with_threads(2), map).unwrap();
        let mut rng = Rng::stream(21, 1);
        let mut out = Vec::new();
        let err = ov.encode_streamed(None, &mut rng, &mut out, &[0.0], &mut |_, _, _| Ok(()), |cb| {
            cb(0, &g);
            0.0
        });
        assert!(err.is_err(), "schedule/section mismatch must be rejected");
    }

    /// A sink error (dead peer) surfaces as `Err` after the round's
    /// encodes drain — no panic, no hang.
    #[test]
    fn streamed_sink_error_propagates() {
        let sp = spans(&[600, 600]);
        let g = vec![0.25f32; 1200];
        let map = SectionMap::new(&sp, 2, 64).unwrap();
        let ready = map.ready_schedule(1.0e6);
        let mut ov = OverlapEncoder::new(&WireSpec::new("terngrad", 64).with_threads(2), map).unwrap();
        let mut rng = Rng::stream(5, 5);
        let mut out = Vec::new();
        let res = ov.encode_streamed(
            None,
            &mut rng,
            &mut out,
            &ready,
            &mut |_, _, _| Err(Error::Comm("peer hung up".into())),
            |cb| {
                for l in (0..sp.len()).rev() {
                    cb(sp[l].start, &g);
                }
                0.0
            },
        );
        assert!(matches!(res, Err(Error::Comm(_))));
    }

    #[test]
    fn ready_schedule_matches_reverse_backward() {
        let sp = spans(&[400, 300, 200, 100]);
        let map = SectionMap::new(&sp, 4, 50).unwrap();
        let ready = map.ready_schedule(1000.0);
        assert_eq!(ready.len(), 4);
        // the last section (produced first) is ready soonest; section 0
        // waits for the whole 1000-element backward
        assert_eq!(ready[0], 1.0);
        for w in ready.windows(2) {
            assert!(w[0] >= w[1], "descending readiness with section index");
        }
        // section 3 owns elements from its bucket-aligned start
        let s3 = &map.sections()[3];
        assert_eq!(ready[3], (map.total() - s3.elems.start) as f64 / 1000.0);
    }

    #[test]
    fn streamed_time_models_degenerate_and_gate_on_readiness() {
        let link = Link::new(1e9, 1e-4);
        // all ready at 0: ps_streamed = serialized uplinks + tail, which
        // is the overlap model over the same byte vector
        let frames = [900usize, 600, 300];
        let ready0 = [0.0; 3];
        let ps = ps_streamed_time(&link, &ready0, &frames, 4000);
        assert!((ps - ps_overlap_time(&link, &ready0, &frames, 4000)).abs() < 1e-15);
        // compute-bound: with fast links the last-ready section's frame
        // is the only exposed comm
        let t = ps_streamed_time(&link, &[1e-3, 2e-3, 3e-3], &frames, 0);
        let last = 3e-3 + link.transfer_time(frames[2]);
        assert!((t - last).abs() < 1e-12, "t={t}");
        // sharded: the slowest shard gates the round
        let sh = sharded_streamed_time(
            &link,
            &[0.0, 0.0],
            &[vec![100, 100], vec![4000, 4000]],
            &[100, 4000],
        );
        let slow: Vec<f64> = [4000usize, 4000].iter().map(|&b| link.transfer_time(b)).collect();
        let want = overlap_round_time(&[0.0, 0.0], &slow, link.transfer_time(4000));
        assert!((sh - want).abs() < 1e-15);
        // hier m==1: the leader star is the streamed leg; l==1 is free
        let lm = LinkMap::new(Link::new(100e9, 0.0), Link::new(1e9, 1e-4));
        assert_eq!(hier_streamed_time(&lm, 1, 1, &[0.0], &[100], 100, 400), 0.0);
        let h = hier_streamed_time(&lm, 4, 4, &[0.0; 2], &[500, 500], 1000, 4000);
        let comm: Vec<f64> = [500usize, 500].iter().map(|&b| lm.inter.transfer_time(b)).collect();
        let want = overlap_round_time(&[0.0; 2], &comm, 0.0) + lm.inter.transfer_time(4000);
        assert!((h - want).abs() < 1e-15);
        // ring streamed = ring overlap over the same schedule
        let r = ring_streamed_time(&link, 4, &[1e-3, 0.0], &[800, 800]);
        assert!((r - ring_overlap_time(&link, 4, &[1e-3, 0.0], &[800, 800])).abs() < 1e-15);
    }

    #[test]
    fn overlap_time_recurrence_and_degeneracies() {
        let link = Link::new(1e9, 1e-4);
        // one section, ready at 0: every wrapper equals its flat model
        let ps = ps_overlap_time(&link, &[0.0], &[1000], 4000);
        assert!((ps - super::super::ring::ps_time(&link, 4, 1000, 4000)).abs() < 1e-15);
        let ring = ring_overlap_time(&link, 4, &[0.0], &[1000]);
        assert!((ring - super::super::ring::allreduce_time(&link, 4, 1000)).abs() < 1e-15);
        let lm = LinkMap::new(Link::new(100e9, 0.0), Link::new(1e9, 1e-4));
        let hier = hier_overlap_time(&lm, 8, 2, &[0.0], &[1000], 4000);
        assert!((hier - super::super::hier::hier_time(&lm, 8, 2, 1000, 4000)).abs() < 1e-12);
        let sh = sharded_overlap_time(&link, 4, &[0.0], &[1000], 4000);
        assert!((sh - super::super::shard::sharded_time(&link, 2, 4, 1000, 4000)).abs() < 1e-15);

        // the recurrence: comm hides behind compute until the tail
        let ready = [1e-3, 2e-3, 3e-3];
        let comm = [4e-4, 4e-4, 4e-4];
        let t = overlap_round_time(&ready, &comm, 5e-4);
        // last section's comm + tail are exposed after compute finishes
        assert!((t - (3e-3 + 4e-4 + 5e-4)).abs() < 1e-12, "t={t}");
        // comm-bound: compute free, sections serialize on the link
        let t = overlap_round_time(&[0.0; 3], &comm, 5e-4);
        assert!((t - (3.0 * 4e-4 + 5e-4)).abs() < 1e-12, "t={t}");
        // never better than max(compute, comm), never worse than the sum
        let (ready, comm) = ([2e-3, 5e-3], [3e-3, 1e-3]);
        let t = overlap_round_time(&ready, &comm, 0.0);
        let (compute, total_comm) = (5e-3, 4e-3);
        assert!(t >= compute.max(total_comm) - 1e-15);
        assert!(t <= compute + total_comm + 1e-15);
    }

    /// The overlapped ps model, in its degenerate all-ready-at-0 case on
    /// a zero-latency link, must agree with the simulator's measured
    /// round time to < 1% — the closed-form/measured contract perfbench
    /// re-checks at scale in the v4 `overlap` section.
    #[test]
    fn overlap_model_matches_measured_sim_time() {
        use crate::comm::collective::{run_once, ExchangeConfig, Topology};
        let n = 4096usize;
        let link = Link::new(1e9, 0.0);
        let spec = WireSpec { seed: 9, ..WireSpec::new("orq-5", 128) };
        let mut rng = Rng::seed_from(3);
        let grads: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut g = vec![0.0f32; n];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        let (mean, stats) =
            run_once(&ExchangeConfig::flat(Topology::Ps, link), &spec, &grads).unwrap();
        // uplink bytes from one worker's encode (size-deterministic, so
        // any worker and any rng give the same length)
        let mut gc = GradCodec::new(&spec).unwrap();
        let mut qg = QuantizedGrad::default();
        let (mut r, mut msg) = (Rng::seed_from(9), Vec::new());
        gc.encode_into(&grads[0], &mut r, &mut qg, &mut msg);
        let mut down = Vec::new();
        codec::encode_fp_into(&mean, &mut down);
        // split the uplink into three "sections", all ready at t = 0: the
        // recurrence degenerates to the flat serialized uplink + broadcast
        let third = msg.len() / 3;
        let up = [third, third, msg.len() - 2 * third];
        let model = ps_overlap_time(&link, &[0.0; 3], &up, down.len());
        let err = (model - stats.sim_time_s).abs() / stats.sim_time_s;
        assert!(err < 0.01, "model {model} vs sim {} ({err})", stats.sim_time_s);
    }
}
