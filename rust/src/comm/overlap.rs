//! Layer-wise gradient bucketing with backward/communication overlap.
//!
//! Real DDP stacks hide communication behind compute by bucketing the
//! gradient per model section and shipping early buckets while later
//! layers are still differentiating. This module brings that structure
//! to the trainer without giving up the repo's bit-identity contract:
//!
//! * [`SectionMap`] — the model-section bucket map, seeded from the
//!   backend's layer structure ([`crate::model::Backend::layer_spans`]).
//!   The map cuts the bucket grid at layer-group boundaries so every
//!   bucket belongs to exactly one section; a bucket straddling a
//!   boundary is owned by the *lower* section, because backward produces
//!   gradients in reverse layer order and the straddling bucket is only
//!   complete once the lower section's layers are done. Section `i` is
//!   therefore ready exactly when the backward frontier reaches its
//!   first owned element.
//! * [`OverlapEncoder`] — the overlap driver. It replicates the parallel
//!   codec's encode exactly — one round key drawn per step, per-bucket
//!   RNG streams keyed by the *global* bucket index
//!   ([`BucketQuantizer::quantize_bucket_stream`]) — but dispatches each
//!   section's buckets to the worker pool the moment backward reports
//!   the section complete, overlapping quantize+encode with the
//!   remaining backward compute. Segments concatenate in ascending
//!   bucket order behind one wire header, so the assembled message is
//!   byte-identical to [`super::collective::GradCodec::encode_into`]'s parallel path
//!   (`threads != 1`) — same wire bytes, same decoded means, same
//!   trained parameters, at every thread count. The exchange itself
//!   still moves that one flat message, which is what keeps ring/hier
//!   per-hop requantization chains (and their RNG draws) untouched.
//! * Closed-form overlapped time models — [`overlap_round_time`] is the
//!   serial-link pipeline recurrence `end_i = max(end_{i-1}, ready_i) +
//!   comm_i` over sections in send (readiness) order, plus the exposed
//!   non-overlappable tail (the mean broadcast). Per-topology wrappers
//!   ([`ps_overlap_time`], [`ring_overlap_time`], [`hier_overlap_time`],
//!   [`sharded_overlap_time`]) extend the flat `ps`/`ring`/`hier`/
//!   `sharded_time` models: with one section ready at time zero each
//!   degenerates to its flat model exactly, and with real section sizes
//!   the comm stays hidden behind compute until the tail.
//!
//! Serial codecs (`threads == 1`) cannot overlap: the legacy encoder
//! advances one RNG across buckets in order and cannot start
//! mid-gradient. The trainer therefore degenerates `--overlap` to the
//! flat path at `threads == 1` (trivially bit-identical), and
//! [`OverlapEncoder::new`] rejects serial specs outright.

use std::ops::Range;

use super::collective::{PoolMode, WireSpec};
use super::link::{Link, LinkMap};
use crate::codec::{self, BucketEncoder, Packing};
use crate::error::{Error, Result};
use crate::quant::bucket::BucketQuantizer;
use crate::quant::pool::PoolHandle;
use crate::quant::{self, QuantizedBucket, Quantizer};
use crate::tensor::rng::Rng;

// --------------------------------------------------------------------
// Closed-form overlapped time models
// --------------------------------------------------------------------

/// Critical path of a section-pipelined exchange over one serial link:
/// section `i` (in send order — the order backward finishes them)
/// becomes ready at `ready_at[i]` and occupies the link for
/// `comm_s[i]`, so `end_i = max(end_{i-1}, ready_at[i]) + comm_s[i]`;
/// the non-overlappable tail (the assembled-mean broadcast) lands after
/// the last section. Comm stays hidden behind compute until the tail:
/// the result is `max(total compute, total comm)` when one side
/// dominates, and never exceeds `compute + comm + tail`.
pub fn overlap_round_time(ready_at: &[f64], comm_s: &[f64], tail_s: f64) -> f64 {
    assert_eq!(ready_at.len(), comm_s.len(), "one comm term per section");
    let mut end = 0.0f64;
    for (&r, &c) in ready_at.iter().zip(comm_s) {
        end = end.max(r) + c;
    }
    end + tail_s
}

/// Overlapped parameter-server round: per-section uplinks pipeline
/// behind compute, the FP mean broadcast is the exposed tail. With one
/// section ready at 0 this is exactly `ring::ps_time`.
pub fn ps_overlap_time(
    link: &Link,
    ready_at: &[f64],
    up_bytes: &[usize],
    down_bytes: usize,
) -> f64 {
    let comm: Vec<f64> = up_bytes.iter().map(|&b| link.transfer_time(b)).collect();
    overlap_round_time(ready_at, &comm, link.transfer_time(down_bytes))
}

/// Overlapped ring round: each section runs its own all-reduce as soon
/// as it is ready; there is no broadcast tail (the all-gather is part of
/// each section's collective). One section at 0 ≡ `ring::allreduce_time`.
pub fn ring_overlap_time(
    link: &Link,
    n: usize,
    ready_at: &[f64],
    section_bytes: &[usize],
) -> f64 {
    let comm: Vec<f64> = section_bytes
        .iter()
        .map(|&b| super::ring::allreduce_time(link, n, b))
        .collect();
    overlap_round_time(ready_at, &comm, 0.0)
}

/// Overlapped hierarchical round: each section's intra reduce-scatter +
/// gather and leader uplink pipeline behind compute; the FP mean
/// multicasts (inter star + intra group) are the exposed tail. One
/// section at 0 ≡ `hier::hier_time`.
pub fn hier_overlap_time(
    links: &LinkMap,
    l: usize,
    groups: usize,
    ready_at: &[f64],
    section_bytes: &[usize],
    fp_bytes: usize,
) -> f64 {
    assert!(l > 0 && groups > 0 && l % groups == 0);
    let m = l / groups;
    if l == 1 {
        return 0.0;
    }
    let up = |q: usize| {
        let mut t = 0.0;
        if m > 1 {
            // m−1 reduce-scatter hops + 1 gather, one q/m chunk each
            let chunk = q as f64 / m as f64;
            t += m as f64 * (links.intra.latency_s + chunk * 8.0 / links.intra.bandwidth_bps);
        }
        if groups > 1 {
            t += links.inter.transfer_time(q);
        }
        t
    };
    let comm: Vec<f64> = section_bytes.iter().map(|&b| up(b)).collect();
    let mut tail = 0.0;
    if m > 1 {
        tail += links.intra.transfer_time(fp_bytes);
    }
    if groups > 1 {
        tail += links.inter.transfer_time(fp_bytes);
    }
    overlap_round_time(ready_at, &comm, tail)
}

/// Overlapped sharded-PS round: per-section uploads stripe across the
/// `S` shards behind compute; the sharded FP downlink is the exposed
/// tail. One section at 0 ≡ `shard::sharded_time`.
pub fn sharded_overlap_time(
    link: &Link,
    shards: usize,
    ready_at: &[f64],
    up_bytes: &[usize],
    down_bytes: usize,
) -> f64 {
    assert!(shards > 0);
    let comm: Vec<f64> = up_bytes
        .iter()
        .map(|&b| link.latency_s + (b as f64 / shards as f64) * 8.0 / link.bandwidth_bps)
        .collect();
    let tail = link.latency_s + (down_bytes as f64 / shards as f64) * 8.0 / link.bandwidth_bps;
    overlap_round_time(ready_at, &comm, tail)
}

// --------------------------------------------------------------------
// Section bucket map
// --------------------------------------------------------------------

/// One model section of the overlap map: a contiguous run of whole
/// buckets (`buckets` are global bucket-grid indices, `elems` the
/// element range those buckets cover, clipped to the gradient length).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Section {
    pub elems: Range<usize>,
    pub buckets: Range<usize>,
}

/// The model-section bucket map: `sections` contiguous groups of layers,
/// balanced to within one layer, cut on the codec's bucket grid so every
/// bucket belongs to exactly one section.
#[derive(Debug, Clone)]
pub struct SectionMap {
    sections: Vec<Section>,
    bucket_size: usize,
    total: usize,
}

impl SectionMap {
    /// Build the map from a backend's layer spans (which must tile
    /// `0..param_count` contiguously). `sections` must be in
    /// `1..=layers`: zero sections is meaningless and more sections than
    /// layers would leave sections without a completion event.
    pub fn new(
        layer_spans: &[Range<usize>],
        sections: usize,
        bucket_size: usize,
    ) -> Result<SectionMap> {
        assert!(bucket_size > 0, "bucket_size is validated upstream");
        let layers = layer_spans.len();
        if layers == 0 {
            return Err(Error::InvalidArg("model reports no layer spans".into()));
        }
        let mut covered = 0usize;
        for (i, s) in layer_spans.iter().enumerate() {
            if s.start != covered || s.end < s.start {
                return Err(Error::InvalidArg(format!(
                    "layer spans must tile the parameter vector contiguously; \
                     span {i} is {s:?} after {covered} covered elements"
                )));
            }
            covered = s.end;
        }
        if sections == 0 {
            return Err(Error::InvalidArg(
                "sections must be at least 1 (got 0)".into(),
            ));
        }
        if sections > layers {
            return Err(Error::InvalidArg(format!(
                "sections ({sections}) exceeds the model's layer count ({layers}); \
                 every overlap section needs at least one layer — reduce sections"
            )));
        }
        let total = covered;
        let d = bucket_size;
        let nb = total.div_ceil(d);
        let boundary = |i: usize| {
            if i == sections {
                total
            } else {
                layer_spans[layers * i / sections].start
            }
        };
        // A bucket straddling a section boundary is owned by the lower
        // section (backward completes high offsets first, so the bucket
        // is only whole once the lower section's layers are done).
        let bucket_cut = |i: usize| {
            if i == sections {
                nb
            } else {
                boundary(i).div_ceil(d).min(nb)
            }
        };
        let mut out = Vec::with_capacity(sections);
        for i in 0..sections {
            let (b0, b1) = (bucket_cut(i), bucket_cut(i + 1));
            out.push(Section {
                elems: (b0 * d).min(total)..(b1 * d).min(total),
                buckets: b0..b1,
            });
        }
        Ok(SectionMap { sections: out, bucket_size: d, total })
    }

    pub fn sections(&self) -> &[Section] {
        &self.sections
    }

    pub fn num_sections(&self) -> usize {
        self.sections.len()
    }

    pub fn total(&self) -> usize {
        self.total
    }

    pub fn bucket_size(&self) -> usize {
        self.bucket_size
    }
}

// --------------------------------------------------------------------
// The overlap driver
// --------------------------------------------------------------------

/// Per-section staging + encode arenas, reused across rounds (the
/// steady-state overlap path allocates nothing per section).
#[derive(Default)]
struct SectionArena {
    /// Staged gradient slice (compensated `g + m` under error feedback).
    gbuf: Vec<f32>,
    /// This section's encoded payload segment.
    seg: Vec<u8>,
    clip: Vec<f32>,
    qb: QuantizedBucket,
}

/// The overlap driver: encodes sections on the worker pool while
/// backward produces the rest of the gradient, then assembles the one
/// flat wire message the topology exchange expects.
pub struct OverlapEncoder {
    map: SectionMap,
    bucketq: BucketQuantizer,
    quantizer: Box<dyn Quantizer>,
    scheme: String,
    packing: Packing,
    levels: usize,
    /// `Some` = pooled section tasks (default); `None` = the legacy
    /// scoped-thread baseline (`--pool false`), one spawn per section.
    pool: Option<PoolHandle>,
    arenas: Vec<SectionArena>,
    section_bytes: Vec<usize>,
}

impl OverlapEncoder {
    /// Build the driver for a parallel quantizing spec. Rejects FP
    /// (no bucket grid to pipeline) and serial (`threads == 1`) specs —
    /// the serial encoder's single RNG stream advances across buckets in
    /// order and cannot start mid-gradient.
    pub fn new(spec: &WireSpec, map: SectionMap) -> Result<OverlapEncoder> {
        let quantizer = quant::from_name(&spec.method)?;
        let levels = quantizer.num_levels();
        if levels == 0 {
            return Err(Error::InvalidArg(
                "overlap needs a quantizing method; fp gradients have no bucket \
                 grid to pipeline (disable overlap or pick a quantized scheme)"
                    .into(),
            ));
        }
        if spec.threads == 1 {
            return Err(Error::InvalidArg(
                "overlap requires the parallel codec (threads != 1); the serial \
                 encoder cannot start mid-gradient"
                    .into(),
            ));
        }
        if map.bucket_size != spec.bucket_size {
            return Err(Error::InvalidArg(format!(
                "section map bucket size ({}) does not match the wire spec ({})",
                map.bucket_size, spec.bucket_size
            )));
        }
        let bucketq = match spec.clip_factor {
            Some(c) => BucketQuantizer::with_clip(spec.bucket_size, c),
            None => BucketQuantizer::new(spec.bucket_size),
        };
        let pool = match &spec.pool {
            PoolMode::Pooled => Some(PoolHandle::new(spec.threads)),
            PoolMode::Shared(h) => Some(h.clone()),
            PoolMode::Scoped => None,
        };
        Ok(OverlapEncoder {
            map,
            bucketq,
            quantizer,
            scheme: spec.method.clone(),
            packing: spec.packing,
            levels,
            pool,
            arenas: Vec::new(),
            section_bytes: Vec::new(),
        })
    }

    pub fn map(&self) -> &SectionMap {
        &self.map
    }

    /// Encoded payload bytes of each section from the last round (the
    /// per-section wire share the overlapped time models take; the
    /// header is common). Empty before the first round.
    pub fn section_bytes(&self) -> &[usize] {
        &self.section_bytes
    }

    /// Drive one overlapped backward+encode: `backward` runs the model's
    /// sectioned backward ([`crate::model::Backend::loss_grad_sections`])
    /// against the provided readiness callback, and every section is
    /// quantized+encoded concurrently with the remaining backward
    /// compute as soon as its first owned element is behind the reported
    /// frontier. Returns the loss; `out` receives the assembled wire
    /// message, byte-identical to
    /// [`super::collective::GradCodec::encode_into`]'s parallel
    /// path on the full gradient (one round key drawn from `rng`, global
    /// per-bucket streams, segments in ascending bucket order).
    ///
    /// `memory` is the error-feedback residual: when present, sections
    /// stage `g[sec] + m[sec]` — elementwise identical to
    /// [`ErrorFeedback::compensate`](crate::quant::error_feedback::ErrorFeedback)
    /// on the full gradient, so EF wire bytes match the flat EF path
    /// bit for bit. The caller owns the residual update (decode the
    /// assembled message, then `compensate` + `update_residual`).
    pub fn encode_overlapped(
        &mut self,
        memory: Option<&[f32]>,
        rng: &mut Rng,
        out: &mut Vec<u8>,
        backward: impl FnOnce(&mut dyn FnMut(usize, &[f32])) -> f32,
    ) -> f32 {
        let n = self.map.total;
        let nsec = self.map.sections.len();
        if let Some(m) = memory {
            assert_eq!(m.len(), n, "EF residual length");
        }
        // Exactly the parallel codec's RNG discipline: one key per round.
        let round_key = rng.next_u64();
        let enc = BucketEncoder::new(self.levels, self.packing);
        while self.arenas.len() < nsec {
            self.arenas.push(SectionArena::default());
        }
        let arenas = &mut self.arenas[..nsec];
        let map = &self.map;
        let bq = &self.bucketq;
        let q = self.quantizer.as_ref();
        let mut loss = 0.0f32;
        match &self.pool {
            Some(pool) => pool
                .scope(|sc| {
                    let mut slots: Vec<Option<&mut SectionArena>> =
                        arenas.iter_mut().map(Some).collect();
                    // Sections ready so far form a suffix [next, nsec).
                    let mut next = nsec;
                    let mut on_ready = |frontier: usize, g: &[f32]| {
                        debug_assert_eq!(g.len(), n, "gradient length");
                        while next > 0 && map.sections[next - 1].elems.start >= frontier {
                            next -= 1;
                            let s = &map.sections[next];
                            let a = slots[next].take().expect("section dispatched once");
                            stage(a, g, memory, &s.elems);
                            let (buckets, e0) = (s.buckets.clone(), s.elems.start);
                            sc.spawn(move || {
                                encode_section(bq, q, round_key, buckets, e0, enc, a)
                            });
                        }
                    };
                    loss = backward(&mut on_ready);
                    debug_assert_eq!(next, 0, "backward must report frontier 0");
                })
                .unwrap_or_else(|e| panic!("overlapped encode failed: {e}")),
            None => std::thread::scope(|scope| {
                let mut slots: Vec<Option<&mut SectionArena>> =
                    arenas.iter_mut().map(Some).collect();
                let mut next = nsec;
                let mut on_ready = |frontier: usize, g: &[f32]| {
                    debug_assert_eq!(g.len(), n, "gradient length");
                    while next > 0 && map.sections[next - 1].elems.start >= frontier {
                        next -= 1;
                        let s = &map.sections[next];
                        let a = slots[next].take().expect("section dispatched once");
                        stage(a, g, memory, &s.elems);
                        let (buckets, e0) = (s.buckets.clone(), s.elems.start);
                        scope.spawn(move || {
                            encode_section(bq, q, round_key, buckets, e0, enc, a)
                        });
                    }
                };
                loss = backward(&mut on_ready);
                debug_assert_eq!(next, 0, "backward must report frontier 0");
            }),
        }
        // Assemble: one header, then every section's segment in ascending
        // bucket order — the exact flat parallel wire layout.
        out.clear();
        codec::encode_quantized_header_into(
            self.levels,
            &self.scheme,
            self.packing,
            n,
            self.bucketq.bucket_size,
            out,
        );
        self.section_bytes.clear();
        for a in &self.arenas[..nsec] {
            self.section_bytes.push(a.seg.len());
            out.extend_from_slice(&a.seg);
        }
        loss
    }
}

/// Copy a section's gradient slice (plus the EF residual, when present)
/// into its staging buffer on the backward thread — the encode task must
/// not borrow the live gradient.
fn stage(a: &mut SectionArena, g: &[f32], memory: Option<&[f32]>, elems: &Range<usize>) {
    a.gbuf.clear();
    match memory {
        Some(m) => a.gbuf.extend(
            g[elems.clone()]
                .iter()
                .zip(&m[elems.clone()])
                .map(|(x, r)| x + r),
        ),
        None => a.gbuf.extend_from_slice(&g[elems.clone()]),
    }
}

/// Quantize and serialize one section's run of buckets into its segment
/// buffer. `buckets` are global grid indices — the RNG stream of bucket
/// `bi` is `Rng::stream(round_key, bi)` exactly as in the flat parallel
/// encode, which is what makes the assembled bytes identical.
fn encode_section(
    bq: &BucketQuantizer,
    q: &dyn Quantizer,
    round_key: u64,
    buckets: Range<usize>,
    elems_start: usize,
    enc: BucketEncoder,
    a: &mut SectionArena,
) {
    a.seg.clear();
    let d = bq.bucket_size;
    for bi in buckets {
        let lo = bi * d - elems_start;
        let hi = (lo + d).min(a.gbuf.len());
        bq.quantize_bucket_stream(&a.gbuf[lo..hi], bi, q, round_key, &mut a.clip, &mut a.qb);
        enc.encode_bucket_into(&a.qb, &mut a.seg);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::comm::collective::GradCodec;
    use crate::quant::bucket::QuantizedGrad;

    fn spans(sizes: &[usize]) -> Vec<Range<usize>> {
        let mut out = Vec::new();
        let mut off = 0;
        for &s in sizes {
            out.push(off..off + s);
            off += s;
        }
        out
    }

    #[test]
    fn section_map_tiles_bucket_grid_and_assigns_straddlers_low() {
        // layers of 100/60/40 elements on a 64 grid: boundaries at 100
        // and 160 both straddle buckets.
        let sp = spans(&[100, 60, 40]);
        let m = SectionMap::new(&sp, 3, 64).unwrap();
        let s = m.sections();
        assert_eq!(s.len(), 3);
        // bucket cuts at ceil(100/64)=2 and ceil(160/64)=3; nb=ceil(200/64)=4
        assert_eq!(s[0].buckets, 0..2);
        assert_eq!(s[1].buckets, 2..3);
        assert_eq!(s[2].buckets, 3..4);
        assert_eq!(s[0].elems, 0..128);
        assert_eq!(s[1].elems, 128..192);
        assert_eq!(s[2].elems, 192..200);
        // the map tiles: buckets and elems are contiguous and complete
        assert_eq!(s.iter().map(|x| x.buckets.len()).sum::<usize>(), 4);
        assert_eq!(s.last().unwrap().elems.end, 200);
        // every owned element starts at or after its section's layer
        // boundary — the readiness threshold is conservative
        assert!(s[1].elems.start >= 100 && s[2].elems.start >= 160);
    }

    #[test]
    fn section_map_tolerates_sections_swallowed_by_one_bucket() {
        // three 10-element layers inside one 64 bucket: middle sections
        // own no buckets; the lowest owns the lot.
        let sp = spans(&[10, 10, 10]);
        let m = SectionMap::new(&sp, 3, 64).unwrap();
        let s = m.sections();
        assert_eq!(s[0].buckets, 0..1);
        assert!(s[1].buckets.is_empty() && s[2].buckets.is_empty());
        assert_eq!(s[0].elems, 0..30);
    }

    #[test]
    fn section_map_rejects_bad_shapes() {
        let sp = spans(&[100, 100]);
        assert!(SectionMap::new(&sp, 0, 64).is_err(), "sections = 0");
        assert!(SectionMap::new(&sp, 3, 64).is_err(), "sections > layers");
        assert!(SectionMap::new(&[], 1, 64).is_err(), "no layers");
        // non-tiling spans
        assert!(SectionMap::new(&[0..10, 20..30], 1, 64).is_err());
        // degenerate single section is fine
        assert!(SectionMap::new(&sp, 1, 64).is_ok());
    }

    /// The assembled overlapped message must be byte-identical to the
    /// flat parallel encode, with identical RNG consumption — plain and
    /// with an error-feedback residual staged section-wise.
    #[test]
    fn overlapped_encode_bit_identical_to_flat_parallel_encode() {
        let sp = spans(&[700, 500, 300, 100]);
        let n = 1600;
        let g: Vec<f32> = (0..n).map(|i| ((i * 31) % 113) as f32 / 113.0 - 0.5).collect();
        let mem: Vec<f32> = (0..n).map(|i| ((i * 7) % 29) as f32 / 290.0).collect();
        for threads in [2usize, 4] {
            for memory in [None, Some(&mem[..])] {
                let spec = WireSpec::new("orq-5", 64).with_threads(threads);
                let map = SectionMap::new(&sp, 3, 64).unwrap();
                let mut ov = OverlapEncoder::new(&spec, map).unwrap();
                let mut rng_a = Rng::stream(9, 1);
                let mut overlapped = Vec::new();
                // a synthetic reverse-layer backward: report frontiers in
                // descending layer order, as the MLP backward does
                let loss = ov.encode_overlapped(memory, &mut rng_a, &mut overlapped, |cb| {
                    for l in (0..sp.len()).rev() {
                        cb(sp[l].start, &g);
                    }
                    1.5
                });
                assert_eq!(loss, 1.5);

                let mut gc = GradCodec::new(&spec).unwrap();
                let mut rng_b = Rng::stream(9, 1);
                let mut qg = QuantizedGrad::default();
                let mut flat = Vec::new();
                let signal: Vec<f32> = match memory {
                    Some(m) => g.iter().zip(m).map(|(a, b)| a + b).collect(),
                    None => g.clone(),
                };
                gc.encode_into(&signal, &mut rng_b, &mut qg, &mut flat);
                assert_eq!(
                    overlapped, flat,
                    "threads={threads} ef={}",
                    memory.is_some()
                );
                assert_eq!(rng_a.next_u64(), rng_b.next_u64(), "RNG draw parity");
                // per-section accounting covers the whole payload
                let header = flat.len() - ov.section_bytes().iter().sum::<usize>();
                assert!(header > 0 && header < 64, "header share {header}");
            }
        }
    }

    /// Scoped (pool-less) execution is the same bytes — the `--pool
    /// false` baseline must stay bit-identical.
    #[test]
    fn overlapped_encode_scoped_matches_pooled() {
        use crate::comm::collective::PoolMode;
        let sp = spans(&[600, 400, 200]);
        let g: Vec<f32> = (0..1200).map(|i| ((i * 13) % 97) as f32 / 97.0 - 0.5).collect();
        let drive = |spec: &WireSpec| {
            let map = SectionMap::new(&sp, 2, 128).unwrap();
            let mut ov = OverlapEncoder::new(spec, map).unwrap();
            let mut rng = Rng::stream(4, 2);
            let mut msg = Vec::new();
            ov.encode_overlapped(None, &mut rng, &mut msg, |cb| {
                for l in (0..sp.len()).rev() {
                    cb(sp[l].start, &g);
                }
                0.0
            });
            msg
        };
        let pooled = drive(&WireSpec::new("terngrad", 128).with_threads(2));
        let scoped = drive(
            &WireSpec::new("terngrad", 128)
                .with_threads(2)
                .with_pool_mode(PoolMode::Scoped),
        );
        assert_eq!(pooled, scoped);
    }

    #[test]
    fn overlap_encoder_rejects_fp_and_serial_specs() {
        let sp = spans(&[128, 128]);
        let map = SectionMap::new(&sp, 2, 64).unwrap();
        assert!(OverlapEncoder::new(&WireSpec::new("fp", 64).with_threads(2), map.clone()).is_err());
        assert!(OverlapEncoder::new(&WireSpec::new("terngrad", 64), map.clone()).is_err());
        // bucket-size mismatch between map and spec
        assert!(
            OverlapEncoder::new(&WireSpec::new("terngrad", 128).with_threads(2), map).is_err()
        );
    }

    #[test]
    fn overlap_time_recurrence_and_degeneracies() {
        let link = Link::new(1e9, 1e-4);
        // one section, ready at 0: every wrapper equals its flat model
        let ps = ps_overlap_time(&link, &[0.0], &[1000], 4000);
        assert!((ps - super::super::ring::ps_time(&link, 4, 1000, 4000)).abs() < 1e-15);
        let ring = ring_overlap_time(&link, 4, &[0.0], &[1000]);
        assert!((ring - super::super::ring::allreduce_time(&link, 4, 1000)).abs() < 1e-15);
        let lm = LinkMap::new(Link::new(100e9, 0.0), Link::new(1e9, 1e-4));
        let hier = hier_overlap_time(&lm, 8, 2, &[0.0], &[1000], 4000);
        assert!((hier - super::super::hier::hier_time(&lm, 8, 2, 1000, 4000)).abs() < 1e-12);
        let sh = sharded_overlap_time(&link, 4, &[0.0], &[1000], 4000);
        assert!((sh - super::super::shard::sharded_time(&link, 2, 4, 1000, 4000)).abs() < 1e-15);

        // the recurrence: comm hides behind compute until the tail
        let ready = [1e-3, 2e-3, 3e-3];
        let comm = [4e-4, 4e-4, 4e-4];
        let t = overlap_round_time(&ready, &comm, 5e-4);
        // last section's comm + tail are exposed after compute finishes
        assert!((t - (3e-3 + 4e-4 + 5e-4)).abs() < 1e-12, "t={t}");
        // comm-bound: compute free, sections serialize on the link
        let t = overlap_round_time(&[0.0; 3], &comm, 5e-4);
        assert!((t - (3.0 * 4e-4 + 5e-4)).abs() < 1e-12, "t={t}");
        // never better than max(compute, comm), never worse than the sum
        let (ready, comm) = ([2e-3, 5e-3], [3e-3, 1e-3]);
        let t = overlap_round_time(&ready, &comm, 0.0);
        let (compute, total_comm) = (5e-3, 4e-3);
        assert!(t >= compute.max(total_comm) - 1e-15);
        assert!(t <= compute + total_comm + 1e-15);
    }

    /// The overlapped ps model, in its degenerate all-ready-at-0 case on
    /// a zero-latency link, must agree with the simulator's measured
    /// round time to < 1% — the closed-form/measured contract perfbench
    /// re-checks at scale in the v4 `overlap` section.
    #[test]
    fn overlap_model_matches_measured_sim_time() {
        use crate::comm::collective::{run_once, ExchangeConfig, Topology};
        let n = 4096usize;
        let link = Link::new(1e9, 0.0);
        let spec = WireSpec { seed: 9, ..WireSpec::new("orq-5", 128) };
        let mut rng = Rng::seed_from(3);
        let grads: Vec<Vec<f32>> = (0..3)
            .map(|_| {
                let mut g = vec![0.0f32; n];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        let (mean, stats) =
            run_once(&ExchangeConfig::flat(Topology::Ps, link), &spec, &grads).unwrap();
        // uplink bytes from one worker's encode (size-deterministic, so
        // any worker and any rng give the same length)
        let mut gc = GradCodec::new(&spec).unwrap();
        let mut qg = QuantizedGrad::default();
        let (mut r, mut msg) = (Rng::seed_from(9), Vec::new());
        gc.encode_into(&grads[0], &mut r, &mut qg, &mut msg);
        let mut down = Vec::new();
        codec::encode_fp_into(&mean, &mut down);
        // split the uplink into three "sections", all ready at t = 0: the
        // recurrence degenerates to the flat serialized uplink + broadcast
        let third = msg.len() / 3;
        let up = [third, third, msg.len() - 2 * third];
        let model = ps_overlap_time(&link, &[0.0; 3], &up, down.len());
        let err = (model - stats.sim_time_s).abs() / stats.sim_time_s;
        assert!(err < 0.01, "model {model} vs sim {} ({err})", stats.sim_time_s);
    }
}
