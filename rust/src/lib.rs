//! # orq — Optimal Gradient Quantization for Communication-Efficient Distributed Training
//!
//! Production-shaped reproduction of *"Optimal Gradient Quantization
//! Condition for Communication-Efficient Distributed Training"* (An Xu,
//! Zhouyuan Huo, Heng Huang, 2020): the ORQ multi-level quantizer
//! (Theorem 1 / Algorithm 1), the BinGrad-pb/BinGrad-b binary quantizers
//! (Eqs. 15/17), and the baselines they are evaluated against (TernGrad,
//! QSGD-s, Linear-s, scaled SignSGD), embedded in a synchronous
//! parameter-server training runtime.
//!
//! Three layers (see DESIGN.md):
//! * **L3 (this crate)** — coordinator: quantize → encode → simulated wire
//!   → decode → average → SGD, plus every substrate (codec, comm model,
//!   datasets, metrics, config, CLI, bench harness).
//! * **L2/L1 (`python/`, build-time only)** — JAX model + Pallas kernels,
//!   AOT-lowered to HLO text executed here through [`runtime`] (PJRT).
//!
//! Quick taste (single bucket):
//! ```
//! use orq::quant::{Quantizer, orq::OrqQuantizer};
//! use orq::tensor::rng::Rng;
//! let q = OrqQuantizer::new(9);
//! let g: Vec<f32> = (0..512).map(|i| ((i * 37 % 101) as f32 - 50.0) / 50.0).collect();
//! let mut rng = Rng::seed_from(7);
//! let qb = q.quantize_bucket(&g, &mut rng);
//! assert_eq!(qb.levels.len(), 9);
//! ```

pub mod bench;
pub mod cli;
pub mod codec;
pub mod comm;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod error;
pub mod metrics;
pub mod model;
pub mod obs;
pub mod quant;
pub mod runtime;
pub mod tensor;
pub mod testutil;
pub mod util;

pub use error::{Error, Result};
