//! Table 2: CIFAR-100 single-worker test accuracy across all 12 methods
//! × 3 model columns (d = 2048, no clipping — §5.1.1).
//!
//! Fast mode (default) uses shrunk stand-in models; `ORQ_BENCH_FULL=1`
//! runs the paper-scale MLP-S/M/L. The *shape* to check against the
//! paper: ORQ-s beats QSGD-s/TernGrad at every s, Linear-s trails, and
//! BinGrad-b leads the ×32 group.

use orq::bench::{print_rows, suite};
use orq::util::csv::CsvWriter;

fn main() {
    let steps = suite::cifar_steps();
    let methods = orq::quant::paper_methods();
    let mut rows = Vec::new();
    let mut csv = CsvWriter::create(
        "artifacts/results/table2.csv",
        &["model", "method", "compression", "top1", "rel_mse"],
    )
    .expect("csv");

    for (col, model, in_dim) in suite::table2_models() {
        let ds = suite::cifar100_ds(in_dim);
        for method in &methods {
            let cfg = suite::cifar_cfg(method, &model, steps);
            let out = suite::run_native(cfg, &ds).expect("run");
            let s = out.summary;
            rows.push(vec![
                col.to_string(),
                method.to_string(),
                format!("×{:.1}", s.compression_ratio),
                format!("{:.2}%", s.test_top1 * 100.0),
                format!("{:.4}", s.mean_quant_rel_mse),
            ]);
            csv.row_str(&[
                col.to_string(),
                method.to_string(),
                format!("{:.2}", s.compression_ratio),
                format!("{:.4}", s.test_top1),
                format!("{:.6}", s.mean_quant_rel_mse),
            ])
            .ok();
            eprintln!("  [{col}] {method}: top1={:.2}%", s.test_top1 * 100.0);
        }
    }
    csv.flush().ok();
    print_rows(
        "Table 2 — CIFAR-100(-like) single-worker test accuracy (d=2048, no clip)",
        &["model", "method", "ratio", "top-1", "quant relMSE"],
        &rows,
    );
    println!("\nCSV: artifacts/results/table2.csv");
    println!("Expected shape (paper): ORQ-s > QSGD-s/TernGrad at equal s; Linear-s worst;");
    println!("BinGrad-b best of the 1-bit group; all below FP.");
}
