//! Figure 1: gradient-value distributions of FP vs quantized gradients
//! (QSGD-9, ORQ-9, Linear-9, BinGrad, TernGrad) on a *real* mid-training
//! gradient, rendered as normalized histograms + the two §5.1.2 criteria:
//! level utilization and shape distortion.

use orq::bench::{print_rows, suite};
use orq::metrics::histogram::Histogram;
use orq::model::Backend;
use orq::quant::bucket::BucketQuantizer;
use orq::tensor::rng::Rng;

fn main() {
    // Train briefly with FP to get a realistic mid-training gradient.
    let (_, model, in_dim) = suite::table2_models().remove(1);
    let ds = suite::cifar100_ds(in_dim);
    let mut cfg = suite::cifar_cfg("fp", &model, suite::cifar_steps() / 4);
    cfg.eval_every = 0;
    let out = suite::run_native(cfg, &ds).expect("warm run");

    let factory = orq::coordinator::trainer::native_backend_factory(&model).expect("model");
    let mut backend = factory(0);
    let mut grad = vec![0.0f32; backend.param_count()];
    let mut rng = Rng::seed_from(99);
    let batch = ds.train_batch(64, &mut rng);
    backend.loss_grad(&out.params, &batch, &mut grad);

    std::fs::create_dir_all("artifacts/results").ok();
    // FP histogram clipped to ±2.5σ exactly as the paper's first panel.
    let h_fp = Histogram::sigma_range(&grad, 2.5, 81);
    h_fp.write_csv("artifacts/results/fig1_fp.csv").expect("csv");

    let bq = BucketQuantizer::new(2048);
    let mut rows = vec![];
    for method in ["qsgd-9", "orq-9", "linear-9", "terngrad", "bingrad-b", "bingrad-pb"] {
        let q = orq::quant::from_name(method).unwrap();
        let qg = bq.quantize(&grad, q.as_ref(), &mut rng);
        let deq = qg.dequantize();
        let mut h = Histogram::new(h_fp.lo, h_fp.hi, 81);
        h.fill(&deq);
        h.write_csv(&format!("artifacts/results/fig1_{method}.csv")).expect("csv");

        // §5.1.2 criteria: (1) level utilization — fraction of levels that
        // receive >1% of the elements; (2) shape distortion — L1 distance
        // between normalized histograms.
        let total = deq.len() as f64;
        let mut used = 0usize;
        let mut levels = 0usize;
        for b in &qg.buckets {
            let mut counts = vec![0usize; b.levels.len()];
            for &i in &b.indices {
                counts[i as usize] += 1;
            }
            used += counts.iter().filter(|&&c| c as f64 > 0.01 * b.indices.len() as f64).count();
            levels += b.levels.len();
        }
        let n_fp = h_fp.normalized();
        let n_q = h.normalized();
        let distortion: f64 =
            n_fp.iter().zip(&n_q).map(|(a, b)| (a - b).abs()).sum::<f64>() / n_fp.len() as f64;
        let err = orq::quant::error::measure(&grad, &qg);
        rows.push(vec![
            method.to_string(),
            format!("{:.1}%", 100.0 * used as f64 / levels as f64),
            format!("{distortion:.4}"),
            format!("{:.5}", err.rel_mse),
            format!("{:.1}%", 100.0 * h.occupancy()),
        ]);
        let _ = total;
        eprintln!("  {method}: utilization/distortion computed");
    }
    print_rows(
        "Figure 1 — level utilization & gradient-shape distortion (lower distortion = better)",
        &["method", "levels >1% used", "shape distortion", "rel MSE", "hist occupancy"],
        &rows,
    );
    println!("\nCSVs: artifacts/results/fig1_*.csv (center,count,normalized)");
    println!("Expected shape (paper): ORQ-9 beats QSGD-9 on utilization AND beats Linear-9 on distortion.");
}
