//! Figure 2: CIFAR-100 training curves — train loss, test accuracy, and
//! quantization error per step for all methods × the three model columns.
//! Emits one series CSV per (model, method) under artifacts/results/.

use orq::bench::{print_rows, suite};

fn main() {
    let steps = suite::cifar_steps();
    let methods = ["fp", "terngrad", "orq-3", "qsgd-5", "orq-5", "linear-5", "qsgd-9", "orq-9", "linear-9"];
    std::fs::create_dir_all("artifacts/results").ok();

    let mut rows = Vec::new();
    for (col, model, in_dim) in suite::table2_models() {
        let ds = suite::cifar100_ds(in_dim);
        for method in methods {
            let mut cfg = suite::cifar_cfg(method, &model, steps);
            cfg.eval_every = (steps / 10).max(1);
            let out = suite::run_native(cfg, &ds).expect("run");
            let tag = format!("{}_{method}", model.replace([':', '-'], "_"));
            out.series
                .write_csv(&format!("artifacts/results/fig2_{tag}_series.csv"))
                .expect("csv");
            out.series
                .write_eval_csv(&format!("artifacts/results/fig2_{tag}_eval.csv"))
                .expect("csv");
            rows.push(vec![
                col.to_string(),
                method.to_string(),
                format!("{:.4}", out.summary.final_train_loss),
                format!("{:.2}%", out.summary.test_top1 * 100.0),
                format!("{:.4}", out.summary.mean_quant_rel_mse),
            ]);
            eprintln!("  [{col}] {method} done");
        }
    }
    print_rows(
        "Figure 2 — final point of each training curve (full series in CSVs)",
        &["model", "method", "final loss", "top-1", "mean quant relMSE"],
        &rows,
    );
    println!("\nCSVs: artifacts/results/fig2_*_series.csv / *_eval.csv");
    println!("Expected shape (paper): ORQ's quant-error curve sits below its counterpart at equal s for the whole run; loss curves track FP most closely for ORQ-9.");
}
