//! Hot-path throughput: per-method quantization rate (level solve +
//! random rounding) on a 4M-element gradient, plus the ORQ ablations —
//! greedy vs refined solver, and solve-vs-round cost split. This is the
//! §Perf workhorse bench (EXPERIMENTS.md §Perf).

use orq::bench::{print_table, Bench};
use orq::quant::bucket::BucketQuantizer;
use orq::quant::orq::OrqQuantizer;
use orq::quant::{self, Quantizer};
use orq::tensor::rng::Rng;

fn main() {
    let n: usize = if std::env::var("ORQ_BENCH_FAST").as_deref() == Ok("1") {
        1 << 20
    } else {
        1 << 22
    };
    let mut rng = Rng::seed_from(1);
    let mut g = vec![0.0f32; n];
    rng.fill_gaussian(&mut g, 1e-3);
    let bench = Bench::from_env();

    // --- per-method end-to-end quantize (d = 2048) ---
    let bq = BucketQuantizer::new(2048);
    let mut rows = Vec::new();
    for method in quant::paper_methods() {
        if method == "fp" {
            continue;
        }
        let q = quant::from_name(method).unwrap();
        let mut qrng = Rng::seed_from(2);
        rows.push(bench.measure(&format!("quantize {method} (d=2048)"), Some(n as u64), || {
            let qg = bq.quantize(&g, q.as_ref(), &mut qrng);
            std::hint::black_box(qg.buckets.len());
        }));
    }
    print_table("Quantize throughput — level solve + rounding, 4M-elt gradient", &rows);

    // --- bucket-size sensitivity for ORQ-3 ---
    let q3 = quant::from_name("orq-3").unwrap();
    let mut rows = Vec::new();
    for d in [128usize, 512, 2048, 8192, 32768] {
        let bqd = BucketQuantizer::new(d);
        let mut qrng = Rng::seed_from(3);
        rows.push(bench.measure(&format!("orq-3 d={d}"), Some(n as u64), || {
            let qg = bqd.quantize(&g, q3.as_ref(), &mut qrng);
            std::hint::black_box(qg.buckets.len());
        }));
    }
    print_table("ORQ-3 throughput vs bucket size (sort cost dominates large d)", &rows);

    // --- ablation: greedy Algorithm 1 vs refined (future-work variant) ---
    let bucket: Vec<f32> = g[..4096].to_vec();
    let mut sorted = bucket.clone();
    sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let mut rows = Vec::new();
    for (name, sweeps) in [("greedy (paper Alg.1)", 0usize), ("refine×4", 4), ("refine×16", 16)] {
        let solver = OrqQuantizer::with_refinement(9, sweeps);
        rows.push(bench.measure(
            &format!("orq-9 solve {name}"),
            Some(4096),
            || {
                std::hint::black_box(solver.levels_for(&bucket));
            },
        ));
    }
    print_table("Ablation — ORQ level-solver variants (one 4096-elt bucket)", &rows);
    // quality side of the ablation
    use orq::quant::error::expected_rr_mse;
    for (name, sweeps) in [("greedy", 0usize), ("refine×4", 4), ("refine×16", 16)] {
        let lv = OrqQuantizer::with_refinement(9, sweeps).levels_for(&bucket);
        println!("  {name}: expected RR-MSE = {:.6e}", expected_rr_mse(&sorted, &lv));
    }

    // --- solve-vs-round split for orq-9 ---
    let solver = OrqQuantizer::new(9);
    let mut rows = Vec::new();
    rows.push(bench.measure("orq-9 solve only (per 2048-bucket)", Some(2048), || {
        std::hint::black_box(solver.levels_for(&g[..2048]));
    }));
    let levels = solver.levels_for(&g[..2048]);
    let mut qrng = Rng::seed_from(4);
    let mut idx = Vec::new();
    rows.push(bench.measure("round only (per 2048-bucket)", Some(2048), || {
        quant::random_round(&g[..2048], &levels, &mut qrng, &mut idx);
        std::hint::black_box(idx.len());
    }));
    print_table("ORQ-9 cost split — solve vs round", &rows);
}
