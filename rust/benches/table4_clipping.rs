//! Table 4: test accuracy vs clipping factor c ∈ {none, 1.7, 2.5} for
//! ORQ-3/5/9 on CIFAR-10 and CIFAR-100 (d = 512, warmup as in §5).
//! Paper shape: clipping closes most of the gap to FP, c=1.7 ≳ c=2.5.

use orq::bench::{print_rows, suite};
use orq::util::csv::CsvWriter;

fn main() {
    let steps = suite::cifar_steps();
    let (model10, model100, in_dim) = if suite::full_scale() {
        ("mlp_m".to_string(), "mlp_m".to_string(), 256)
    } else {
        ("mlp:64-192-192-10".to_string(), "mlp:64-192-192-100".to_string(), 64)
    };
    let ds10 = suite::cifar10_ds(in_dim);
    let ds100 = suite::cifar100_ds(in_dim);

    let mut csv = CsvWriter::create(
        "artifacts/results/table4.csv",
        &["dataset", "method", "clip", "top1"],
    )
    .expect("csv");
    let mut rows = Vec::new();
    for (ds_name, ds, model) in [("CIFAR-10", &ds10, &model10), ("CIFAR-100", &ds100, &model100)] {
        // FP reference for the (±x.xx) deltas the paper prints
        let mut fp_cfg = suite::cifar_cfg("fp", model, steps);
        fp_cfg.bucket_size = 512;
        let fp = suite::run_native(fp_cfg, ds).expect("fp").summary.test_top1;
        for method in ["orq-3", "orq-5", "orq-9"] {
            for clip in [None, Some(1.7f32), Some(2.5f32)] {
                let mut cfg = suite::cifar_cfg(method, model, steps);
                cfg.bucket_size = 512;
                cfg.clip_factor = clip;
                if clip.is_some() {
                    cfg.warmup_steps = steps / 40; // paper's 5-of-200-epoch warmup
                }
                let out = suite::run_native(cfg, ds).expect("run");
                let t1 = out.summary.test_top1;
                let clip_label = clip.map(|c| format!("c={c}")).unwrap_or("noclip".into());
                rows.push(vec![
                    ds_name.to_string(),
                    method.to_string(),
                    clip_label.clone(),
                    format!("{:.2}% ({:+.2})", t1 * 100.0, (t1 - fp) * 100.0),
                ]);
                csv.row_str(&[
                    ds_name.into(),
                    method.into(),
                    clip_label,
                    format!("{t1:.4}"),
                ])
                .ok();
                eprintln!("  {ds_name} {method} clip={clip:?}: {:.2}%", t1 * 100.0);
            }
        }
    }
    csv.flush().ok();
    print_rows(
        "Table 4 — accuracy vs clipping factor (d=512, warmup w/ clip); Δ vs FP in parens",
        &["dataset", "method", "clip", "top-1 (Δ vs FP)"],
        &rows,
    );
    println!("\nCSV: artifacts/results/table4.csv");
    println!("Expected shape (paper): clipping ≥ noclip for 3-level; c=1.7 ≳ c=2.5; deltas shrink with s.");
}
