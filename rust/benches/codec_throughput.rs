//! Wire-codec throughput: encode/decode rates for fixed-width vs base-s
//! packing, and the exact wire-size table per scheme.

use orq::bench::{print_rows, print_table, Bench};
use orq::codec::{self, Packing};
use orq::quant::bucket::BucketQuantizer;
use orq::quant::{self};
use orq::tensor::rng::Rng;
use orq::util::fmt;

fn main() {
    let n: usize = if std::env::var("ORQ_BENCH_FAST").as_deref() == Ok("1") {
        1 << 20
    } else {
        1 << 22
    };
    let mut rng = Rng::seed_from(1);
    let mut g = vec![0.0f32; n];
    rng.fill_gaussian(&mut g, 1e-3);
    let bench = Bench::from_env();
    let bq = BucketQuantizer::new(2048);

    let mut enc_rows = Vec::new();
    let mut dec_rows = Vec::new();
    let mut size_rows = Vec::new();
    for method in ["bingrad-b", "terngrad", "qsgd-5", "orq-9"] {
        let q = quant::from_name(method).unwrap();
        let qg = bq.quantize(&g, q.as_ref(), &mut rng);
        for packing in [Packing::Fixed, Packing::BaseS] {
            let label = format!("{method} {packing:?}");
            enc_rows.push(bench.measure(&format!("encode {label}"), Some(n as u64), || {
                std::hint::black_box(codec::encode(&qg, method, packing).len());
            }));
            let bytes = codec::encode(&qg, method, packing);
            dec_rows.push(bench.measure(&format!("decode {label}"), Some(n as u64), || {
                std::hint::black_box(codec::decode(&bytes).unwrap().len());
            }));
            size_rows.push(vec![
                label,
                fmt::bytes(bytes.len() as u64),
                format!("×{:.2}", (n * 4) as f64 / bytes.len() as f64),
            ]);
        }
    }
    // FP baseline
    enc_rows.push(bench.measure("encode fp32", Some(n as u64), || {
        std::hint::black_box(codec::encode_fp(&g).len());
    }));
    let fp_bytes = codec::encode_fp(&g);
    dec_rows.push(bench.measure("decode fp32", Some(n as u64), || {
        std::hint::black_box(codec::decode(&fp_bytes).unwrap().len());
    }));

    print_table("Encode throughput — 4M-elt gradient, d=2048", &enc_rows);
    print_table("Decode throughput (incl. dequantize)", &dec_rows);
    print_rows(
        "Exact wire sizes (fp32 = 16 MiB)",
        &["scheme+packing", "wire size", "ratio"],
        &size_rows,
    );
    println!("\nExpected: BaseS hits the paper's ×20.2/×13.8/×10.1 ideal ratios; Fixed trades ~20% size for faster packing.");
}
