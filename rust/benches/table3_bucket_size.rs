//! Table 3: CIFAR-10 accuracy vs bucket size d ∈ {128 … 32768} for
//! TernGrad-noclip vs ORQ-3. Paper finding: accuracy degrades as buckets
//! grow (one level table must cover more heterogeneous values) and ORQ
//! degrades *more slowly*.

use orq::bench::{print_rows, suite};
use orq::util::csv::CsvWriter;

fn main() {
    let steps = suite::cifar_steps();
    // model must have ≥ 32768 params so the largest bucket is meaningful
    let (model, in_dim) = if suite::full_scale() {
        ("mlp_m".to_string(), 256)
    } else {
        ("mlp:64-192-192-10".to_string(), 64)
    };
    let ds = suite::cifar10_ds(in_dim);
    let buckets = [128usize, 512, 1024, 2048, 4096, 8192, 16384, 32768];

    let mut csv = CsvWriter::create(
        "artifacts/results/table3.csv",
        &["bucket", "method", "top1", "rel_mse"],
    )
    .expect("csv");
    let mut rows = Vec::new();
    for method in ["terngrad", "orq-3"] {
        let mut row = vec![method.to_string()];
        for &d in &buckets {
            let mut cfg = suite::cifar_cfg(method, &model, steps);
            cfg.dataset = "cifar10".into();
            cfg.bucket_size = d;
            let out = suite::run_native(cfg, &ds).expect("run");
            row.push(format!("{:.2}", out.summary.test_top1 * 100.0));
            csv.row(&[
                d as f64,
                if method == "orq-3" { 1.0 } else { 0.0 },
                out.summary.test_top1,
                out.summary.mean_quant_rel_mse,
            ])
            .ok();
            eprintln!("  {method} d={d}: top1={:.2}%", out.summary.test_top1 * 100.0);
        }
        rows.push(row);
    }
    csv.flush().ok();
    let mut header = vec!["method"];
    let labels: Vec<String> = buckets.iter().map(|b| b.to_string()).collect();
    header.extend(labels.iter().map(|s| s.as_str()));
    print_rows(
        "Table 3 — CIFAR-10(-like) top-1 (%) vs bucket size: TernGrad-noclip vs ORQ-3",
        &header,
        &rows,
    );
    println!("\nCSV: artifacts/results/table3.csv");
    println!("Expected shape (paper): both degrade with d; ORQ-3 degrades less (paper: 4.58% vs 5.23% over 128→32768).");
}
