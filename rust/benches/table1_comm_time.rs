//! Table 1: #parameters and communication time of one gradient at
//! 10 Gbps for the paper's model zoo — extended with the wire sizes and
//! times of every quantization scheme (exact codec accounting), plus the
//! topology comparison the paper motivates in §4: the closed-form models
//! (PS star, ring all-reduce, two-level hierarchy) AND measured rounds
//! over the real executable topologies (`comm::run_once`), side by side.

use orq::bench::print_rows;
use orq::codec::{wire_size, Packing};
use orq::comm::link::{Link, LinkMap};
use orq::comm::{hier, ring, run_once, shard, ExchangeConfig, PoolMode, Topology, WireSpec};
use orq::quant::pool::PoolHandle;
use orq::tensor::rng::Rng;
use orq::util::fmt;

const ZOO: [(&str, u64); 5] = [
    ("AlexNet", 61_100_000),
    ("VGG-19", 143_700_000),
    ("DenseNet-161", 28_700_000),
    ("GoogLeNet", 13_000_000),
    ("ResNet-50", 25_600_000),
];

fn main() {
    let link = Link::ten_gbps();
    let d = 512; // the paper's ImageNet bucket size
    // One persistent worker pool for every measured round below: codecs
    // and shard servers across all the run_once calls reuse the same
    // threads (the cross-round amortization perfbench quantifies).
    let pool = PoolHandle::new(0);
    let pooled = |spec: WireSpec| spec.with_pool_mode(PoolMode::Shared(pool.clone()));

    // --- the paper's exact table: FP32 comm time ---
    let mut rows = Vec::new();
    for (name, params) in ZOO {
        let bytes = params as usize * 4;
        rows.push(vec![
            name.to_string(),
            format!("{:.1} M", params as f64 / 1e6),
            fmt::duration(link.transfer_time(bytes)),
        ]);
    }
    print_rows(
        "Table 1 — #Parameter and FP32 comm time @ 10 Gbps (paper rows)",
        &["model", "#parameter", "comm time"],
        &rows,
    );

    // --- extension: per-scheme wire size and comm time (exact codec) ---
    let schemes: [(&str, usize); 5] = [
        ("fp", 0),
        ("bingrad-b", 2),
        ("terngrad", 3),
        ("orq-5", 5),
        ("orq-9", 9),
    ];
    let mut rows = Vec::new();
    for (name, params) in ZOO {
        for (scheme, s) in schemes {
            let bytes = wire_size(params as usize, d, s, Packing::BaseS, scheme);
            rows.push(vec![
                name.to_string(),
                scheme.to_string(),
                fmt::bytes(bytes as u64),
                format!("×{:.1}", (params as f64 * 4.0) / bytes as f64),
                fmt::duration(link.transfer_time(bytes)),
            ]);
        }
    }
    print_rows(
        "Table 1 (extended) — quantized wire size & comm time, d=512, base-s packing",
        &["model", "scheme", "wire size", "ratio", "comm time"],
        &rows,
    );

    // --- topology ablation (modeled): PS vs ring for ResNet-50 ---
    let bytes_fp = 25_600_000usize * 4;
    let bytes_q3 = wire_size(25_600_000, d, 3, Packing::BaseS, "terngrad");
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16] {
        rows.push(vec![
            format!("{n} workers"),
            fmt::duration(ring::ps_time(&link, n, bytes_fp, bytes_fp)),
            fmt::duration(ring::allreduce_time(&link, n, bytes_fp)),
            fmt::duration(ring::ps_time(&link, n, bytes_q3, bytes_fp)),
            fmt::duration(ring::quantized_ring_time(&link, n, bytes_q3)),
        ]);
    }
    print_rows(
        "Topology ablation (ResNet-50, modeled): PS vs ring, FP vs 3-level",
        &["cluster", "PS fp32", "ring fp32", "PS 3-level up", "ring 3-level"],
        &rows,
    );

    // --- topology ablation (measured): one round over the REAL executable
    // collectives (mpsc channels, per-hop decode-reduce-requantize),
    // scaled-down gradient so the bench stays fast. The "model" column is
    // the closed-form prediction for the same per-node byte volume; the
    // measured ring pays per-chunk headers + level tables on top.
    let n_elems = 1usize << 21; // 2.1M elements ≈ 8.4 MB fp32
    let mut rows = Vec::new();
    for workers in [2usize, 4, 8] {
        let mut rng = Rng::seed_from(42);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| {
                let mut g = vec![0.0f32; n_elems];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        for (scheme, s) in [("fp", 0usize), ("terngrad", 3)] {
            let spec = pooled(WireSpec { seed: 7, ..WireSpec::new(scheme, d) });
            let ps_cfg = ExchangeConfig::flat(Topology::Ps, link);
            let ring_cfg = ExchangeConfig::flat(Topology::Ring, link);
            let (_, ps) = run_once(&ps_cfg, &spec, &grads).expect("ps round");
            let (_, rg) = run_once(&ring_cfg, &spec, &grads).expect("ring round");
            let one = wire_size(n_elems, d, s, Packing::BaseS, scheme);
            rows.push(vec![
                format!("{workers} workers"),
                scheme.to_string(),
                fmt::duration(ps.sim_time_s),
                fmt::duration(rg.sim_time_s),
                fmt::duration(ring::allreduce_time(&link, workers, one)),
                fmt::bytes(rg.wire_bytes),
            ]);
        }
    }
    print_rows(
        "Topology (measured, 2.1M elements over real channels): PS vs ring vs ring model",
        &["cluster", "scheme", "PS measured", "ring measured", "ring model", "ring bytes"],
        &rows,
    );

    // --- hierarchical topology on a heterogeneous cluster: fast
    // 100 Gbps intra-rack links, slow 1 Gbps / 5 ms cross-rack links
    // (the TernGrad-style scenario that motivates compressing harder on
    // the inter-node edges). Measured rounds over the real two-level
    // collective next to the closed-form `hier::hier_time` model; the
    // measured figure pays exact per-chunk header/level-table overhead.
    let links = LinkMap::new(Link::new(100e9, 1e-6), Link::new(1e9, 0.005));
    let n_elems = 1usize << 21;
    let mut rows = Vec::new();
    for (workers, groups) in [(8usize, 2usize), (8, 4), (16, 4)] {
        let mut rng = Rng::seed_from(42);
        let grads: Vec<Vec<f32>> = (0..workers)
            .map(|_| {
                let mut g = vec![0.0f32; n_elems];
                rng.fill_gaussian(&mut g, 1e-3);
                g
            })
            .collect();
        for (scheme, s) in [("fp", 0usize), ("terngrad", 3)] {
            let spec = pooled(WireSpec { seed: 7, ..WireSpec::new(scheme, d) });
            let hier_cfg = ExchangeConfig::hier(groups, links);
            let (_, h) = run_once(&hier_cfg, &spec, &grads).expect("hier round");
            let ps_cfg = ExchangeConfig { links, ..ExchangeConfig::flat(Topology::Ps, link) };
            let (_, ps) = run_once(&ps_cfg, &spec, &grads).expect("ps round");
            let q_bytes = wire_size(n_elems, d, s, Packing::BaseS, scheme);
            let fp_bytes = n_elems * 4;
            let model = hier::hier_time(&links, workers, groups, q_bytes, fp_bytes);
            rows.push(vec![
                format!("{workers}w/{groups}g"),
                scheme.to_string(),
                fmt::duration(h.sim_time_s),
                fmt::duration(model),
                fmt::duration(ps.sim_time_s),
                fmt::bytes(h.wire_bytes_intra),
                fmt::bytes(h.wire_bytes_inter),
            ]);
        }
    }
    print_rows(
        "Hierarchical (measured, 100G intra / 1G+5ms inter): hier vs model vs flat PS",
        &[
            "cluster",
            "scheme",
            "hier measured",
            "hier model",
            "PS measured",
            "intra bytes",
            "inter bytes",
        ],
        &rows,
    );

    // --- sharded parameter server: the star's bandwidth bottleneck cut
    // S ways (each shard serves one bucket-aligned chunk in its own
    // thread). Measured one-round times over the real collective next to
    // the closed-form `shard::sharded_time` model, plus the async
    // amortization `shard::async_time` predicts for a latency-bearing
    // link with a staleness window K.
    let n_elems = 1usize << 21;
    let workers = 4usize;
    let mut rng = Rng::seed_from(42);
    let grads: Vec<Vec<f32>> = (0..workers)
        .map(|_| {
            let mut g = vec![0.0f32; n_elems];
            rng.fill_gaussian(&mut g, 1e-3);
            g
        })
        .collect();
    let mut rows = Vec::new();
    for (scheme, s) in [("fp", 0usize), ("terngrad", 3)] {
        let spec = pooled(WireSpec { seed: 7, ..WireSpec::new(scheme, d) });
        let up = wire_size(n_elems, d, s, Packing::BaseS, scheme);
        let down = n_elems * 4;
        for shards in [1usize, 2, 4, 8] {
            let cfg = ExchangeConfig::sharded(shards, 0, link);
            let (_, st) = run_once(&cfg, &spec, &grads).expect("sharded round");
            rows.push(vec![
                format!("S={shards}"),
                scheme.to_string(),
                fmt::duration(st.sim_time_s),
                fmt::duration(shard::sharded_time(&link, workers, shards, up, down)),
                fmt::bytes(st.wire_bytes),
            ]);
        }
    }
    print_rows(
        &format!("Sharded PS (measured, {workers} workers, 2.1M elements): round vs model"),
        &["shards", "scheme", "measured", "model", "wire bytes"],
        &rows,
    );

    // Async amortization (modeled): 100 rounds of the terngrad gradient on
    // a 1 Gbps / 5 ms star — the latency term shrinks with the window.
    let slow = Link::new(1e9, 0.005);
    let up = wire_size(n_elems, d, 3, Packing::BaseS, "terngrad");
    let down = n_elems * 4;
    let mut rows = Vec::new();
    for k in [0usize, 1, 4, 16] {
        rows.push(vec![
            format!("K={k}"),
            fmt::duration(shard::async_time(&slow, workers, 4, 100, k, up, down)),
        ]);
    }
    print_rows(
        "Async sharded PS (modeled, 100 rounds @ 1 Gbps + 5 ms, S=4): staleness window",
        &["window", "total comm time"],
        &rows,
    );
}
