//! Table 1: #parameters and communication time of one gradient at
//! 10 Gbps for the paper's model zoo — extended with the wire sizes and
//! times of every quantization scheme (exact codec accounting), plus the
//! ring-all-reduce comparison the paper mentions in §4.

use orq::bench::print_rows;
use orq::codec::{wire_size, Packing};
use orq::comm::link::Link;
use orq::comm::ring;
use orq::util::fmt;

const ZOO: [(&str, u64); 5] = [
    ("AlexNet", 61_100_000),
    ("VGG-19", 143_700_000),
    ("DenseNet-161", 28_700_000),
    ("GoogLeNet", 13_000_000),
    ("ResNet-50", 25_600_000),
];

fn main() {
    let link = Link::ten_gbps();
    let d = 512; // the paper's ImageNet bucket size

    // --- the paper's exact table: FP32 comm time ---
    let mut rows = Vec::new();
    for (name, params) in ZOO {
        let bytes = params as usize * 4;
        rows.push(vec![
            name.to_string(),
            format!("{:.1} M", params as f64 / 1e6),
            fmt::duration(link.transfer_time(bytes)),
        ]);
    }
    print_rows(
        "Table 1 — #Parameter and FP32 comm time @ 10 Gbps (paper rows)",
        &["model", "#parameter", "comm time"],
        &rows,
    );

    // --- extension: per-scheme wire size and comm time (exact codec) ---
    let schemes: [(&str, usize); 5] = [
        ("fp", 0),
        ("bingrad-b", 2),
        ("terngrad", 3),
        ("orq-5", 5),
        ("orq-9", 9),
    ];
    let mut rows = Vec::new();
    for (name, params) in ZOO {
        for (scheme, s) in schemes {
            let bytes = wire_size(params as usize, d, s, Packing::BaseS, scheme);
            rows.push(vec![
                name.to_string(),
                scheme.to_string(),
                fmt::bytes(bytes as u64),
                format!("×{:.1}", (params as f64 * 4.0) / bytes as f64),
                fmt::duration(link.transfer_time(bytes)),
            ]);
        }
    }
    print_rows(
        "Table 1 (extended) — quantized wire size & comm time, d=512, base-s packing",
        &["model", "scheme", "wire size", "ratio", "comm time"],
        &rows,
    );

    // --- topology ablation: PS vs ring all-reduce for ResNet-50 ---
    let bytes_fp = 25_600_000usize * 4;
    let bytes_q3 = wire_size(25_600_000, d, 3, Packing::BaseS, "terngrad");
    let mut rows = Vec::new();
    for n in [2usize, 4, 8, 16] {
        rows.push(vec![
            format!("{n} workers"),
            fmt::duration(ring::ps_time(&link, n, bytes_fp, bytes_fp)),
            fmt::duration(ring::allreduce_time(&link, n, bytes_fp)),
            fmt::duration(ring::ps_time(&link, n, bytes_q3, bytes_fp)),
            fmt::duration(ring::quantized_ring_time(&link, n, bytes_q3)),
        ]);
    }
    print_rows(
        "Topology ablation (ResNet-50): PS vs ring, FP vs 3-level",
        &["cluster", "PS fp32", "ring fp32", "PS 3-level up", "ring 3-level"],
        &rows,
    );
}
