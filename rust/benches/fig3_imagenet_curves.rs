//! Figure 3: ImageNet(-like) distributed training curves (4 workers,
//! d = 512, clip 2.5σ): loss + accuracy series per method.

use orq::bench::{print_rows, suite};

fn main() {
    let steps = suite::imagenet_steps();
    let (model, in_dim) = if suite::full_scale() {
        ("mlp_l".to_string(), 512)
    } else {
        ("mlp:128-256-256-200".to_string(), 128)
    };
    let ds = suite::imagenet_ds(in_dim);
    std::fs::create_dir_all("artifacts/results").ok();

    let mut rows = Vec::new();
    for method in ["fp", "terngrad", "orq-3", "qsgd-5", "orq-5", "qsgd-9", "orq-9"] {
        let mut cfg = suite::cifar_cfg(method, &model, steps);
        cfg.dataset = "imagenet".into();
        cfg.workers = 4;
        cfg.batch = 256;
        cfg.bucket_size = 512;
        cfg.weight_decay = 1e-4;
        cfg.eval_every = (steps / 10).max(1);
        if method != "fp" {
            cfg.clip_factor = Some(2.5);
            cfg.warmup_steps = steps / 18;
        }
        let out = suite::run_native(cfg, &ds).expect("run");
        out.series
            .write_csv(&format!("artifacts/results/fig3_{method}_series.csv"))
            .expect("csv");
        out.series
            .write_eval_csv(&format!("artifacts/results/fig3_{method}_eval.csv"))
            .expect("csv");
        rows.push(vec![
            method.to_string(),
            format!("{:.4}", out.summary.final_train_loss),
            format!("{:.2}%", out.summary.test_top1 * 100.0),
            format!("{:.2}%", out.summary.test_top5 * 100.0),
            format!("{:.4}", out.summary.mean_quant_rel_mse),
        ]);
        eprintln!("  {method} done");
    }
    print_rows(
        "Figure 3 — final point of each distributed curve (full series in CSVs)",
        &["method", "final loss", "top-1", "top-5", "mean quant relMSE"],
        &rows,
    );
    println!("\nCSVs: artifacts/results/fig3_*_series.csv / *_eval.csv");
    println!("Expected shape (paper): ORQ-5/9 curves nearly overlap FP; TernGrad trails; ordering preserved from single-worker runs.");
}
