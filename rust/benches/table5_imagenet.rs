//! Table 5: ImageNet(-like) distributed training, 4 workers, d = 512,
//! clipping 2.5σ + warmup: top-1/top-5 for FP, TernGrad/ORQ-3,
//! QSGD-5/ORQ-5, QSGD-9/ORQ-9.
//!
//! Paper shape: ORQ-s beats its counterpart at every compression ratio
//! (~1.3% top-1), and ORQ-3 ≈ QSGD-5/9.

use orq::bench::{print_rows, suite};
use orq::util::csv::CsvWriter;

fn main() {
    let steps = suite::imagenet_steps();
    let (model, in_dim) = if suite::full_scale() {
        ("mlp_l".to_string(), 512)
    } else {
        ("mlp:128-256-256-200".to_string(), 128)
    };
    let ds = suite::imagenet_ds(in_dim);
    let methods: [(&str, &str); 7] = [
        ("fp", "×1"),
        ("terngrad", "×20.2"),
        ("orq-3", "×20.2"),
        ("qsgd-5", "×13.8"),
        ("orq-5", "×13.8"),
        ("qsgd-9", "×10.1"),
        ("orq-9", "×10.1"),
    ];

    let mut csv = CsvWriter::create(
        "artifacts/results/table5.csv",
        &["method", "top1", "top5", "comm_time_s", "wire_bytes"],
    )
    .expect("csv");
    let mut rows = Vec::new();
    let mut fp_acc = (0.0, 0.0);
    for (method, ratio) in methods {
        let mut cfg = suite::cifar_cfg(method, &model, steps);
        cfg.dataset = "imagenet".into();
        cfg.workers = 4;
        cfg.batch = 256; // paper: 256 total, split onto 4 workers
        cfg.bucket_size = 512;
        cfg.weight_decay = 1e-4; // paper §5.2
        if method != "fp" {
            cfg.clip_factor = Some(2.5);
            cfg.warmup_steps = steps / 18; // paper's 5-of-90-epoch warmup
        }
        let out = suite::run_native(cfg, &ds).expect("run");
        let s = out.summary;
        if method == "fp" {
            fp_acc = (s.test_top1, s.test_top5);
        }
        rows.push(vec![
            ratio.to_string(),
            method.to_string(),
            format!("{:.2}% ({:+.2})", s.test_top1 * 100.0, (s.test_top1 - fp_acc.0) * 100.0),
            format!("{:.2}% ({:+.2})", s.test_top5 * 100.0, (s.test_top5 - fp_acc.1) * 100.0),
            format!("{:.3}s", s.total_comm_time_s),
        ]);
        csv.row_str(&[
            method.into(),
            format!("{:.4}", s.test_top1),
            format!("{:.4}", s.test_top5),
            format!("{:.4}", s.total_comm_time_s),
            s.total_wire_bytes.to_string(),
        ])
        .ok();
        eprintln!("  {method}: top1={:.2}% top5={:.2}%", s.test_top1 * 100.0, s.test_top5 * 100.0);
    }
    csv.flush().ok();
    print_rows(
        "Table 5 — ImageNet(-like), 4 workers, d=512, clip 2.5σ (Δ vs FP in parens)",
        &["ratio", "method", "top-1", "top-5", "sim comm time"],
        &rows,
    );
    println!("\nCSV: artifacts/results/table5.csv");
    println!("Expected shape (paper): ORQ > counterpart at every ratio; ORQ-3 ≈ QSGD-5/9; gap shrinks as ratio drops.");
}
