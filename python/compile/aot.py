"""AOT export: lower every requested model to HLO *text* + a meta manifest.

Interchange format is HLO **text**, not ``.serialize()``: jax ≥ 0.5 emits
HloModuleProtos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
re-assigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Outputs, per model ``<name>``:
  artifacts/<name>.grad.hlo.txt   (flat_params, *batch) -> (loss, flat_grad)
  artifacts/<name>.fwd.hlo.txt    (flat_params, x|tokens) -> (logits,)
  artifacts/meta.json             manifest consumed by rust/src/runtime

Run via ``make artifacts`` (no-op when inputs are unchanged). Python never
runs again after this step — the Rust binary is self-contained.
"""

import argparse
import json
import os
import sys

import jax
from jax._src.lib import xla_client as xc

from .model import param_count, registry

DEFAULT_MODELS = ["mlp_s", "transformer_s"]


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_model(name: str, outdir: str) -> dict:
    mdef = registry()[name]()
    p = param_count(mdef.sections)
    flat = jax.ShapeDtypeStruct((p,), jax.numpy.float32)

    grad_path = os.path.join(outdir, f"{name}.grad.hlo.txt")
    fwd_path = os.path.join(outdir, f"{name}.fwd.hlo.txt")

    print(f"[aot] {name}: lowering grad ({p:,} params) ...", flush=True)
    grad_hlo = to_hlo_text(jax.jit(mdef.grad_fn).lower(flat, *mdef.grad_args))
    with open(grad_path, "w") as f:
        f.write(grad_hlo)

    print(f"[aot] {name}: lowering fwd ...", flush=True)
    fwd_hlo = to_hlo_text(jax.jit(mdef.predict_fn).lower(flat, *mdef.predict_args))
    with open(fwd_path, "w") as f:
        f.write(fwd_hlo)

    def arg_desc(s):
        return {"shape": list(s.shape), "dtype": s.dtype.name}

    return {
        "name": name,
        "kind": mdef.kind,
        "param_count": p,
        "grad_hlo": os.path.basename(grad_path),
        "fwd_hlo": os.path.basename(fwd_path),
        "grad_args": [arg_desc(s) for s in mdef.grad_args],
        "predict_args": [arg_desc(s) for s in mdef.predict_args],
        "sections": [
            {
                "name": s.name,
                "shape": list(s.shape),
                "init": s.init,
                "fan_in": s.fan_in,
                "size": s.size,
            }
            for s in mdef.sections
        ],
        "config": mdef.meta,
    }


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts/meta.json",
                    help="path of the meta manifest; HLO files go next to it")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS),
                    help="comma-separated model names from the registry")
    args = ap.parse_args(argv)

    outdir = os.path.dirname(os.path.abspath(args.out)) or "."
    os.makedirs(outdir, exist_ok=True)
    names = [n for n in args.models.split(",") if n]
    unknown = sorted(set(names) - set(registry()))
    if unknown:
        print(f"[aot] unknown models: {unknown}; known: {sorted(registry())}")
        return 2

    manifest = {"models": [export_model(n, outdir) for n in names]}
    with open(args.out, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"[aot] wrote {args.out} ({len(names)} models)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
