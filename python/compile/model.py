"""Layer-2 JAX models: forward + loss + backward over ONE flat parameter vector.

The Rust coordinator owns the optimizer and the quantized-communication
path, so every model here exposes exactly two jittable entry points:

* ``grad_fn(flat_params, *batch) -> (loss, flat_grad)`` — what a worker
  executes per step (lowered to ``artifacts/<name>.grad.hlo.txt``);
* ``predict_fn(flat_params, x) -> logits`` — evaluation
  (``artifacts/<name>.fwd.hlo.txt``).

Parameters live in a single ``f32[P]`` vector (concatenation of the named
sections listed in the model's :class:`ParamSpec`), because the paper's
quantizers operate on the *flattened* gradient bucketed into fixed-size
buckets — the Rust side never needs to know the tree structure, only P and
the init recipe per section (exported to ``artifacts/meta.json``).

All matmuls route through the Layer-1 Pallas ``dense``/``matmul_pallas``
kernels so the hot spot lowers into the same HLO module.
"""

import dataclasses
import math
from typing import Callable, Dict, List, Sequence, Tuple

import jax
import jax.numpy as jnp

from .kernels.dense import dense, matmul_pallas


@dataclasses.dataclass(frozen=True)
class Section:
    """One named parameter tensor inside the flat vector."""

    name: str
    shape: Tuple[int, ...]
    init: str  # "he" | "xavier" | "normal02" | "zeros" | "ones"

    @property
    def size(self) -> int:
        return int(math.prod(self.shape))

    @property
    def fan_in(self) -> int:
        return int(self.shape[0]) if len(self.shape) >= 2 else self.size


def param_count(sections: Sequence[Section]) -> int:
    return sum(s.size for s in sections)


def unflatten(flat: jnp.ndarray, sections: Sequence[Section]) -> Dict[str, jnp.ndarray]:
    """Slice the flat vector into named tensors (static offsets → fusable)."""
    out, off = {}, 0
    for s in sections:
        out[s.name] = jax.lax.dynamic_slice(flat, (off,), (s.size,)).reshape(s.shape)
        off += s.size
    return out


def init_flat(sections: Sequence[Section], key) -> jnp.ndarray:
    """Reference initializer (tests only — Rust does its own, same recipe)."""
    chunks = []
    for s in sections:
        key, sub = jax.random.split(key)
        if s.init == "he":
            std = math.sqrt(2.0 / s.fan_in)
            chunks.append(jax.random.normal(sub, s.shape) * std)
        elif s.init == "xavier":
            std = math.sqrt(1.0 / s.fan_in)
            chunks.append(jax.random.normal(sub, s.shape) * std)
        elif s.init == "normal02":
            chunks.append(jax.random.normal(sub, s.shape) * 0.02)
        elif s.init == "zeros":
            chunks.append(jnp.zeros(s.shape))
        elif s.init == "ones":
            chunks.append(jnp.ones(s.shape))
        else:
            raise ValueError(s.init)
    return jnp.concatenate([c.reshape(-1) for c in chunks]).astype(jnp.float32)


# --------------------------------------------------------------------------
# MLP classifier (the CIFAR-substitute model family)
# --------------------------------------------------------------------------


def mlp_sections(in_dim: int, hidden: Sequence[int], classes: int) -> List[Section]:
    dims = [in_dim, *hidden, classes]
    secs: List[Section] = []
    for i, (a, b) in enumerate(zip(dims[:-1], dims[1:])):
        secs.append(Section(f"w{i}", (a, b), "he"))
        secs.append(Section(f"b{i}", (b,), "zeros"))
    return secs


def mlp_logits(flat, x, sections, n_layers):
    p = unflatten(flat, sections)
    h = x
    for i in range(n_layers):
        act = "relu" if i < n_layers - 1 else "linear"
        h = dense(h, p[f"w{i}"], p[f"b{i}"], act)
    return h


def softmax_xent(logits, labels):
    """Mean softmax cross-entropy; labels are int32 class ids."""
    logz = jax.nn.logsumexp(logits, axis=-1)
    picked = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - picked)


def make_mlp(in_dim: int, hidden: Sequence[int], classes: int):
    sections = mlp_sections(in_dim, hidden, classes)
    n_layers = len(hidden) + 1

    def predict(flat, x):
        return (mlp_logits(flat, x, sections, n_layers),)

    def loss(flat, x, y):
        return softmax_xent(mlp_logits(flat, x, sections, n_layers), y)

    def grad(flat, x, y):
        l, g = jax.value_and_grad(loss)(flat, x, y)
        return (l, g)

    return sections, predict, grad


# --------------------------------------------------------------------------
# Transformer LM (the e2e-validation model; 100M config provided)
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerCfg:
    vocab: int
    d_model: int
    n_heads: int
    n_layers: int
    seq_len: int  # number of *predicted* positions; inputs are seq_len + 1 tokens
    d_ff: int

    @property
    def head_dim(self) -> int:
        return self.d_model // self.n_heads


def transformer_sections(cfg: TransformerCfg) -> List[Section]:
    d, f = cfg.d_model, cfg.d_ff
    secs = [
        Section("embed", (cfg.vocab, d), "normal02"),
        Section("pos", (cfg.seq_len, d), "normal02"),
    ]
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        secs += [
            Section(pre + "ln1.g", (d,), "ones"),
            Section(pre + "ln1.b", (d,), "zeros"),
            Section(pre + "wq", (d, d), "xavier"),
            Section(pre + "wk", (d, d), "xavier"),
            Section(pre + "wv", (d, d), "xavier"),
            Section(pre + "wo", (d, d), "xavier"),
            Section(pre + "bo", (d,), "zeros"),
            Section(pre + "ln2.g", (d,), "ones"),
            Section(pre + "ln2.b", (d,), "zeros"),
            Section(pre + "w1", (d, f), "he"),
            Section(pre + "b1", (f,), "zeros"),
            Section(pre + "w2", (f, d), "xavier"),
            Section(pre + "b2", (d,), "zeros"),
        ]
    secs += [
        Section("lnf.g", (d,), "ones"),
        Section("lnf.b", (d,), "zeros"),
        Section("unembed", (d, cfg.vocab), "xavier"),
    ]
    return secs


def _layernorm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(x, p, pre, cfg: TransformerCfg):
    b, t, d = x.shape
    x2 = x.reshape(b * t, d)
    q = matmul_pallas(x2, p[pre + "wq"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    k = matmul_pallas(x2, p[pre + "wk"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    v = matmul_pallas(x2, p[pre + "wv"]).reshape(b, t, cfg.n_heads, cfg.head_dim)
    scores = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(cfg.head_dim)
    mask = jnp.tril(jnp.ones((t, t), dtype=bool))
    scores = jnp.where(mask[None, None], scores, -1e30)
    att = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhqk,bkhd->bqhd", att, v).reshape(b * t, d)
    return dense(ctx, p[pre + "wo"], p[pre + "bo"], "linear").reshape(b, t, d)


def transformer_logits(flat, tokens, cfg: TransformerCfg, sections):
    """tokens: int32[B, T]; returns logits f32[B, T, vocab]."""
    p = unflatten(flat, sections)
    b, t = tokens.shape
    h = jnp.take(p["embed"], tokens, axis=0) + p["pos"][None, :t]
    for i in range(cfg.n_layers):
        pre = f"l{i}."
        h = h + _attention(_layernorm(h, p[pre + "ln1.g"], p[pre + "ln1.b"]), p, pre, cfg)
        z = _layernorm(h, p[pre + "ln2.g"], p[pre + "ln2.b"]).reshape(b * t, cfg.d_model)
        z = dense(z, p[pre + "w1"], p[pre + "b1"], "gelu")
        z = dense(z, p[pre + "w2"], p[pre + "b2"], "linear")
        h = h + z.reshape(b, t, cfg.d_model)
    h = _layernorm(h, p["lnf.g"], p["lnf.b"]).reshape(b * t, cfg.d_model)
    return matmul_pallas(h, p["unembed"]).reshape(b, t, cfg.vocab)


def make_transformer(cfg: TransformerCfg):
    sections = transformer_sections(cfg)

    def predict(flat, tokens):
        return (transformer_logits(flat, tokens, cfg, sections),)

    def loss(flat, tokens):
        # tokens: int32[B, T+1]; predict position i+1 from prefix ≤ i.
        inp, tgt = tokens[:, :-1], tokens[:, 1:]
        logits = transformer_logits(flat, inp, cfg, sections)
        logz = jax.nn.logsumexp(logits, axis=-1)
        picked = jnp.take_along_axis(logits, tgt[..., None], axis=-1)[..., 0]
        return jnp.mean(logz - picked)

    def grad(flat, tokens):
        l, g = jax.value_and_grad(loss)(flat, tokens)
        return (l, g)

    return sections, predict, grad


# --------------------------------------------------------------------------
# Registry — every config the Rust side can ask for by name
# --------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ModelDef:
    name: str
    kind: str  # "classifier" | "lm"
    sections: List[Section]
    grad_fn: Callable
    predict_fn: Callable
    grad_args: tuple  # ShapeDtypeStructs (excluding flat params)
    predict_args: tuple
    meta: dict


def _classifier_def(name, in_dim, hidden, classes, batch) -> ModelDef:
    sections, predict, grad = make_mlp(in_dim, hidden, classes)
    x = jax.ShapeDtypeStruct((batch, in_dim), jnp.float32)
    y = jax.ShapeDtypeStruct((batch,), jnp.int32)
    return ModelDef(
        name, "classifier", sections, grad, predict, (x, y), (x,),
        {"in_dim": in_dim, "hidden": list(hidden), "classes": classes, "batch": batch},
    )


def _lm_def(name, cfg: TransformerCfg, batch) -> ModelDef:
    sections, predict, grad = make_transformer(cfg)
    tok = jax.ShapeDtypeStruct((batch, cfg.seq_len + 1), jnp.int32)
    inp = jax.ShapeDtypeStruct((batch, cfg.seq_len), jnp.int32)
    return ModelDef(
        name, "lm", sections, grad, predict, (tok,), (inp,),
        {
            "vocab": cfg.vocab, "d_model": cfg.d_model, "n_heads": cfg.n_heads,
            "n_layers": cfg.n_layers, "seq_len": cfg.seq_len, "d_ff": cfg.d_ff,
            "batch": batch,
        },
    )


def registry() -> Dict[str, Callable[[], ModelDef]]:
    """Lazy registry: building a ModelDef is cheap, lowering is not."""
    return {
        # CIFAR-substitute classifier family (paper Table 2 columns).
        "mlp_s": lambda: _classifier_def("mlp_s", 256, [512, 512], 100, 64),
        "mlp_m": lambda: _classifier_def("mlp_m", 256, [1024, 1024, 1024], 100, 64),
        "mlp_l": lambda: _classifier_def("mlp_l", 512, [2048, 2048, 2048], 200, 64),
        # e2e-validation LM (~0.9M) — trained for a few hundred steps in
        # examples/e2e_transformer.rs.
        "transformer_s": lambda: _lm_def(
            "transformer_s",
            TransformerCfg(vocab=256, d_model=128, n_heads=4, n_layers=2,
                           seq_len=64, d_ff=512),
            batch=8,
        ),
        # ~26M — ResNet-50-scale parameter count for distributed runs.
        "transformer_m": lambda: _lm_def(
            "transformer_m",
            TransformerCfg(vocab=4096, d_model=512, n_heads=8, n_layers=6,
                           seq_len=128, d_ff=2048),
            batch=8,
        ),
        # ~110M — the paper-scale config (compile-heavy; build on demand).
        "transformer_100m": lambda: _lm_def(
            "transformer_100m",
            TransformerCfg(vocab=32768, d_model=768, n_heads=12, n_layers=12,
                           seq_len=256, d_ff=3072),
            batch=4,
        ),
    }
