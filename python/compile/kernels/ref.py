"""Pure-jnp oracles for every Pallas kernel.

The pytest suite (``python/tests/``) asserts the Pallas kernels agree with
these to float tolerance across hypothesis-generated shapes and dtypes.
These are also the semantics the Rust hot path re-implements, so agreement
here transitively pins the whole stack to one definition.
"""

import jax.numpy as jnp


def act_ref(z, activation):
    if activation == "linear":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        return 0.5 * z * (1.0 + jnp.tanh(c * (z + 0.044715 * z**3)))
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(activation)


def dense_ref(x, w, b, activation="relu"):
    """activation(x @ w + b) in plain jnp."""
    return act_ref(x @ w + b[None, :], activation)


def matmul_ref(x, w):
    return x @ w


def bucket_stats_ref(g):
    """(min, max, sum, sumsq, l1) per bucket row, each f32[nb, 1]."""
    return (
        jnp.min(g, axis=-1, keepdims=True),
        jnp.max(g, axis=-1, keepdims=True),
        jnp.sum(g, axis=-1, keepdims=True),
        jnp.sum(g * g, axis=-1, keepdims=True),
        jnp.sum(jnp.abs(g), axis=-1, keepdims=True),
    )


def stochastic_quantize_ref(g, levels, u):
    """Eq. (7) random rounding, vectorized jnp reference.

    Identical math to the Pallas kernel: bracket via count-of-levels-≤-v,
    round up with probability (v - b_lo)/(b_hi - b_lo), clamp outside the
    level range.
    """
    nb, d = g.shape
    s = levels.shape[-1]
    ge = g[..., None] >= levels[:, None, :]
    lower = jnp.clip(jnp.sum(ge.astype(jnp.int32), axis=-1) - 1, 0, s - 2)
    b_lo = jnp.take_along_axis(levels, lower, axis=-1)
    b_hi = jnp.take_along_axis(levels, lower + 1, axis=-1)
    width = b_hi - b_lo
    p = jnp.where(width > 0, (g - b_lo) / jnp.where(width > 0, width, 1.0), 0.0)
    p = jnp.clip(p, 0.0, 1.0)
    return lower + (u < p).astype(jnp.int32)


def quantize_expectation_ref(g, levels):
    """E[dequant(Q(v))] under Eq. (7) — used for unbiasedness tests.

    For v inside [b_min, b_max] this equals v exactly; outside it clamps.
    """
    s = levels.shape[-1]
    ge = g[..., None] >= levels[:, None, :]
    lower = jnp.clip(jnp.sum(ge.astype(jnp.int32), axis=-1) - 1, 0, s - 2)
    b_lo = jnp.take_along_axis(levels, lower, axis=-1)
    b_hi = jnp.take_along_axis(levels, lower + 1, axis=-1)
    width = b_hi - b_lo
    p = jnp.where(width > 0, (g - b_lo) / jnp.where(width > 0, width, 1.0), 0.0)
    p = jnp.clip(p, 0.0, 1.0)
    return b_lo + p * width
