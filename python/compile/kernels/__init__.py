"""Layer-1 Pallas kernels for the ORQ/BinGrad stack.

All kernels are authored with ``interpret=True`` so the lowered HLO contains
plain ops runnable on any PJRT backend (the Rust CPU client in particular).
Each kernel has a pure-jnp oracle in :mod:`compile.kernels.ref`; the pytest
suite asserts elementwise agreement across shapes and dtypes.
"""

from .dense import dense, matmul_pallas
from .quant_stats import bucket_stats
from .quantize import stochastic_quantize

__all__ = ["dense", "matmul_pallas", "bucket_stats", "stochastic_quantize"]
