"""Fused dense layer (matmul + bias + activation) as a Pallas kernel.

This is the model's compute hot spot: every MLP layer and every transformer
projection routes through :func:`dense`.  The kernel is tile-blocked the way
a TPU implementation would be:

* grid ``(M/bm, N/bn, K/bk)`` — the K axis is the innermost (fastest) grid
  dimension so the f32 accumulator block stays resident in VMEM across the
  K loop (output ``BlockSpec`` maps every k step to the same (i, j) block);
* block sizes are chosen as the largest divisors ≤ 128 of each dim, i.e.
  MXU-shaped (128, 128) tiles whenever the model dims allow it;
* bias add + activation are fused into the final K step, so the activation
  never round-trips through HBM.

``interpret=True`` keeps the lowering CPU-runnable (plain HLO, no Mosaic
custom-call); the BlockSpec structure is what we cost for the TPU estimate
in DESIGN.md §Hardware-Adaptation.

``jax.grad`` cannot differentiate through ``pallas_call``, so :func:`dense`
carries a ``custom_vjp`` whose backward pass reuses the same Pallas matmul
kernel for ``dx = dz @ W^T`` and ``dW = x^T @ dz``.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Activations supported by the fused kernel. "linear" is identity.
ACTIVATIONS = ("linear", "relu", "gelu", "tanh")


def _act(z, activation):
    if activation == "linear":
        return z
    if activation == "relu":
        return jnp.maximum(z, 0.0)
    if activation == "gelu":
        # tanh-approximation GELU (same formula in ref.py and in the Rust
        # native backend so all three agree bit-for-bit-ish).
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        return 0.5 * z * (1.0 + jnp.tanh(c * (z + 0.044715 * z**3)))
    if activation == "tanh":
        return jnp.tanh(z)
    raise ValueError(f"unknown activation {activation!r}")


def _act_grad(z, activation):
    """d activation / d z evaluated at pre-activation z."""
    if activation == "linear":
        return jnp.ones_like(z)
    if activation == "relu":
        return (z > 0.0).astype(z.dtype)
    if activation == "gelu":
        c = jnp.sqrt(2.0 / jnp.pi).astype(z.dtype)
        u = c * (z + 0.044715 * z**3)
        t = jnp.tanh(u)
        du = c * (1.0 + 3 * 0.044715 * z**2)
        return 0.5 * (1.0 + t) + 0.5 * z * (1.0 - t**2) * du
    if activation == "tanh":
        return 1.0 - jnp.tanh(z) ** 2
    raise ValueError(f"unknown activation {activation!r}")


def _block(dim: int, target: int = 128) -> int:
    """Largest divisor of ``dim`` that is ≤ target (MXU-tile shaped)."""
    if dim <= target:
        return dim
    for b in range(target, 0, -1):
        if dim % b == 0:
            return b
    return dim


def _matmul_kernel(x_ref, w_ref, o_ref, *, nk):
    """Blocked matmul with VMEM-resident accumulation over the K grid axis."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )
    del nk  # epilogue handled by the fused variant


def _dense_kernel(x_ref, w_ref, b_ref, o_ref, *, nk, activation):
    """Matmul + fused bias/activation epilogue on the last K step."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        o_ref[...] = _act(o_ref[...] + b_ref[...], activation)


def _matmul_impl(x, w):
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"matmul shape mismatch {x.shape} @ {w.shape}"
    bm, bn, bk = _block(m), _block(n), _block(k)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, nk=nk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w)


@jax.custom_vjp
def matmul_pallas(x, w):
    """Pallas blocked matmul ``x @ w`` (no bias, no activation).

    Differentiable: ``pallas_call`` has no JVP rule, so the VJP is supplied
    explicitly — both cotangent matmuls reuse the same Pallas kernel.
    Shapes must be 2-D; any dims work because blocks are chosen as divisors.
    """
    return _matmul_impl(x, w)


def _matmul_vjp_fwd(x, w):
    return _matmul_impl(x, w), (x, w)


def _matmul_vjp_bwd(res, dy):
    x, w = res
    return _matmul_impl(dy, w.T), _matmul_impl(x.T, dy)


matmul_pallas.defvjp(_matmul_vjp_fwd, _matmul_vjp_bwd)


def _dense_fwd_impl(x, w, b, activation):
    m, k = x.shape
    _, n = w.shape
    bm, bn, bk = _block(m), _block(n), _block(k)
    nk = k // bk
    grid = (m // bm, n // bn, nk)
    return pl.pallas_call(
        functools.partial(_dense_kernel, nk=nk, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((1, bn), lambda i, j, kk: (0, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b.reshape(1, n))


@functools.partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, activation="relu"):
    """Fused ``activation(x @ w + b)`` with a Pallas forward and backward.

    Args:
      x: ``f32[M, K]`` input activations.
      w: ``f32[K, N]`` weights.
      b: ``f32[N]`` bias.
      activation: one of :data:`ACTIVATIONS`.

    Returns:
      ``f32[M, N]``.
    """
    return _dense_fwd_impl(x, w, b, activation)


def _dense_vjp_fwd(x, w, b, activation):
    # Save the pre-activation z for the activation gradient; recomputing it
    # with a second Pallas matmul would double the FLOPs of the hot layer.
    z = _dense_fwd_impl(x, w, b, "linear")
    y = _act(z, activation)
    return y, (x, w, z)


def _dense_vjp_bwd(activation, res, dy):
    x, w, z = res
    dz = dy * _act_grad(z, activation)
    dx = matmul_pallas(dz, w.T)
    dw = matmul_pallas(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


dense.defvjp(_dense_vjp_fwd, _dense_vjp_bwd)
