"""Stochastic-rounding quantization against a per-bucket level table (Pallas).

This is the on-device half of the wire path: given sorted quantization
levels ``b_{-(s-1)/2} … b_{(s-1)/2}`` per bucket (produced by any of the
solvers — evenly spaced for TernGrad/QSGD, CDF quantiles for Linear,
Eq. (11) optimal for ORQ), emit the random-rounding level *index* of every
element per Eq. (7):

    Q(v) = b_{k-1}  with prob (b_k - v)/(b_k - b_{k-1})
           b_k      with prob (v - b_{k-1})/(b_k - b_{k-1})

The kernel is branch-free: with s ≤ 16 levels the bracketing index is a
broadcast compare-and-sum (``Σ_k 1[v ≥ b_k] - 1``) rather than a search —
exactly the vectorization a TPU VPU wants (and what the Rust hot path
mirrors with its LUT variant). Values outside the level range clamp to the
extreme levels, which realizes the clipping semantics of BinGrad-pb
(Eq. 14) when called with s = 2.

Output is ``int32`` indices; dequantization is a gather from the level
table (``levels[bucket, idx]``), done here for the model-side check and in
Rust for the wire decode.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _quantize_kernel(g_ref, levels_ref, u_ref, idx_ref):
    v = g_ref[...]            # (1, d)
    lv = levels_ref[...]      # (1, s)
    u = u_ref[...]            # (1, d) iid U[0,1)

    # Bracketing lower index: number of levels <= v, minus one, clamped so
    # that v below b_min rounds "up" from the bottom bracket and v above
    # b_max clamps into the top bracket.
    s = lv.shape[-1]
    ge = v[..., None] >= lv[:, None, :]  # (1, d, s) broadcast compare
    lower = jnp.sum(ge.astype(jnp.int32), axis=-1) - 1
    lower = jnp.clip(lower, 0, s - 2)

    b_lo = jnp.take_along_axis(
        jnp.broadcast_to(lv[:, None, :], ge.shape), lower[..., None], axis=-1
    )[..., 0]
    b_hi = jnp.take_along_axis(
        jnp.broadcast_to(lv[:, None, :], ge.shape), lower[..., None] + 1, axis=-1
    )[..., 0]

    width = b_hi - b_lo
    # p = prob of rounding UP to b_hi; clamp handles v outside [b_lo, b_hi]
    # (p saturates to 0/1) and zero-width intervals.
    p = jnp.where(width > 0, (v - b_lo) / jnp.where(width > 0, width, 1.0), 0.0)
    p = jnp.clip(p, 0.0, 1.0)
    idx_ref[...] = lower + (u < p).astype(jnp.int32)


def stochastic_quantize(g, levels, u):
    """Random-rounding quantization to per-bucket levels.

    Args:
      g: ``f32[num_buckets, d]`` bucketed gradient.
      levels: ``f32[num_buckets, s]`` sorted levels per bucket.
      u: ``f32[num_buckets, d]`` iid uniforms in [0, 1).

    Returns:
      ``int32[num_buckets, d]`` level indices (dequantize by gathering
      ``levels`` at these indices).
    """
    nb, d = g.shape
    _, s = levels.shape
    return pl.pallas_call(
        _quantize_kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((1, d), lambda i: (i, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
            pl.BlockSpec((1, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((nb, d), jnp.int32),
        interpret=True,
    )(g, levels, u)


def dequantize(levels, idx):
    """Gather levels back out of the index tensor (pure jnp)."""
    return jnp.take_along_axis(levels, idx, axis=-1)
