"""Per-bucket gradient statistics as a single-pass Pallas kernel.

Every level solver in the paper consumes bucket statistics before placing
levels: TernGrad needs ``max|v|``, QSGD the bucket range, the 2.5σ clip of
Eq. (TernGrad) needs σ, BinGrad-b's Eq. (17) fixed point starts from the
mean, and ORQ's Algorithm 1 needs the support endpoints (Corollary 1.1).

On a GPU the paper computes these with framework reductions; the TPU-shaped
version is one HBM→VMEM sweep per bucket producing all five moments at once
(min, max, Σv, Σv², Σ|v|), i.e. the bucket row is read exactly once.

Grid: one program per bucket row; the bucket (length d = 512…32768 floats,
2 KiB…128 KiB) fits VMEM comfortably, matching the (8, 128) VPU lane tiling.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _stats_kernel(g_ref, min_ref, max_ref, sum_ref, sumsq_ref, l1_ref):
    row = g_ref[...]
    min_ref[...] = jnp.min(row, axis=-1, keepdims=True)
    max_ref[...] = jnp.max(row, axis=-1, keepdims=True)
    sum_ref[...] = jnp.sum(row, axis=-1, keepdims=True)
    sumsq_ref[...] = jnp.sum(row * row, axis=-1, keepdims=True)
    l1_ref[...] = jnp.sum(jnp.abs(row), axis=-1, keepdims=True)


def bucket_stats(g):
    """Fused per-bucket stats.

    Args:
      g: ``f32[num_buckets, d]`` bucketed flat gradient.

    Returns:
      Tuple ``(min, max, sum, sumsq, l1)``, each ``f32[num_buckets, 1]``.
    """
    nb, d = g.shape
    out = jax.ShapeDtypeStruct((nb, 1), g.dtype)
    return pl.pallas_call(
        _stats_kernel,
        grid=(nb,),
        in_specs=[pl.BlockSpec((1, d), lambda i: (i, 0))],
        out_specs=tuple(pl.BlockSpec((1, 1), lambda i: (i, 0)) for _ in range(5)),
        out_shape=(out,) * 5,
        interpret=True,
    )(g)
