"""Make `import compile.*` work regardless of pytest invocation directory
(repo root `pytest python/tests/` or `cd python && pytest tests/`)."""

import os
import sys

sys.path.insert(0, os.path.dirname(os.path.abspath(__file__)))
