"""AOT export checks: HLO text well-formedness + manifest consistency.

These validate the artifacts the Rust runtime consumes without needing the
Rust side (which has its own integration test through PJRT).
"""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.aot import to_hlo_text, export_model
from compile.model import registry, param_count, init_flat

jax.config.update("jax_platform_name", "cpu")

ART = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_to_hlo_text_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    text = to_hlo_text(jax.jit(fn).lower(spec, spec))
    assert "HloModule" in text
    assert "f32[2,2]" in text
    # text (not proto) is the interchange contract
    assert text.lstrip().startswith("HloModule")


def test_export_model_writes_files(tmp_path):
    entry = export_model("mlp_s", str(tmp_path))
    grad = tmp_path / entry["grad_hlo"]
    fwd = tmp_path / entry["fwd_hlo"]
    assert grad.exists() and fwd.exists()
    text = grad.read_text()
    assert text.startswith("HloModule")
    p = entry["param_count"]
    assert f"f32[{p}]" in text, "flat grad output must appear in the HLO"
    assert entry["kind"] == "classifier"
    assert sum(s["size"] for s in entry["sections"]) == p


@pytest.mark.skipif(not os.path.exists(os.path.join(ART, "meta.json")),
                    reason="artifacts not built (run `make artifacts`)")
class TestBuiltArtifacts:
    @pytest.fixture(scope="class")
    def manifest(self):
        with open(os.path.join(ART, "meta.json")) as f:
            return json.load(f)

    def test_manifest_models_exist(self, manifest):
        for m in manifest["models"]:
            assert os.path.exists(os.path.join(ART, m["grad_hlo"]))
            assert os.path.exists(os.path.join(ART, m["fwd_hlo"]))

    def test_manifest_matches_registry(self, manifest):
        reg = registry()
        for m in manifest["models"]:
            md = reg[m["name"]]()
            assert m["param_count"] == param_count(md.sections)
            assert [s["name"] for s in m["sections"]] == [s.name for s in md.sections]

    def test_hlo_entry_signature(self, manifest):
        """The HLO ENTRY must take flat params first, then the batch args."""
        for m in manifest["models"]:
            text = open(os.path.join(ART, m["grad_hlo"])).read()
            p = m["param_count"]
            assert f"f32[{p}]" in text
            entry_lines = [l for l in text.splitlines() if "ENTRY" in l]
            assert entry_lines, "no ENTRY computation found"


def test_hlo_numerics_roundtrip_via_jax_runtime():
    """Execute the lowered grad through jax itself and compare with eager.

    This is the python-side equivalent of the Rust PJRT integration test:
    lowering must not change numerics.
    """
    md = registry()["mlp_s"]()
    flat = init_flat(md.sections, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 256))
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 100)

    eager_loss, eager_grad = md.grad_fn(flat, x, y)
    compiled = jax.jit(md.grad_fn).lower(flat, x, y).compile()
    jit_loss, jit_grad = compiled(flat, x, y)
    np.testing.assert_allclose(float(eager_loss), float(jit_loss), rtol=1e-5)
    np.testing.assert_allclose(eager_grad, jit_grad, rtol=1e-4, atol=1e-5)
