"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes/dtypes; this is the core correctness signal for
the whole stack — Rust re-implements the ref.py semantics, so kernel==ref
pins all three layers to one definition.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import dense, matmul_pallas, bucket_stats, stochastic_quantize
from compile.kernels.dense import ACTIVATIONS, _block
from compile.kernels.quantize import dequantize
from compile.kernels import ref

jax.config.update("jax_platform_name", "cpu")

DIMS = st.sampled_from([1, 2, 3, 7, 16, 32, 64, 100, 128, 200, 256])
SMALL_DIMS = st.sampled_from([1, 2, 5, 8, 16, 33, 64])


def rand(key, shape, scale=1.0):
    return jax.random.normal(jax.random.PRNGKey(key), shape) * scale


# ---------------------------------------------------------------- dense ---


class TestBlockChoice:
    def test_block_divides(self):
        for d in [1, 2, 7, 100, 128, 129, 256, 300, 2048, 4096]:
            b = _block(d)
            assert d % b == 0
            assert b <= 128 or b == d

    def test_block_is_maximal(self):
        assert _block(256) == 128
        assert _block(100) == 100
        assert _block(300) == 100  # largest divisor of 300 that is <= 128


@settings(max_examples=20, deadline=None)
@given(m=SMALL_DIMS, k=DIMS, n=DIMS, act=st.sampled_from(ACTIVATIONS),
       seed=st.integers(0, 2**16))
def test_dense_matches_ref(m, k, n, act, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n), 0.1)
    b = rand(seed + 2, (n,), 0.1)
    got = dense(x, w, b, act)
    want = ref.dense_ref(x, w, b, act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(max_examples=10, deadline=None)
@given(m=SMALL_DIMS, k=SMALL_DIMS, n=SMALL_DIMS, seed=st.integers(0, 2**16))
def test_matmul_matches_ref(m, k, n, seed):
    x = rand(seed, (m, k))
    w = rand(seed + 1, (k, n))
    np.testing.assert_allclose(matmul_pallas(x, w), ref.matmul_ref(x, w),
                               rtol=1e-4, atol=1e-4)


@settings(max_examples=8, deadline=None)
@given(act=st.sampled_from(ACTIVATIONS), seed=st.integers(0, 2**16))
def test_dense_grad_matches_ref(act, seed):
    x = rand(seed, (16, 32))
    w = rand(seed + 1, (32, 24), 0.2)
    b = rand(seed + 2, (24,), 0.1)

    def f_pallas(x, w, b):
        return jnp.sum(jnp.sin(dense(x, w, b, act)))

    def f_ref(x, w, b):
        return jnp.sum(jnp.sin(ref.dense_ref(x, w, b, act)))

    g = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(g, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_matmul_grad_matches_ref():
    x = rand(7, (8, 16))
    w = rand(8, (16, 8))
    g = jax.grad(lambda x, w: jnp.sum(matmul_pallas(x, w) ** 2), argnums=(0, 1))(x, w)
    gr = jax.grad(lambda x, w: jnp.sum((x @ w) ** 2), argnums=(0, 1))(x, w)
    for a, e in zip(g, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-4)


def test_dense_jit_compiles():
    x, w, b = rand(0, (64, 128)), rand(1, (128, 128), 0.1), rand(2, (128,), 0.1)
    out = jax.jit(lambda x, w, b: dense(x, w, b, "relu"))(x, w, b)
    np.testing.assert_allclose(out, ref.dense_ref(x, w, b, "relu"),
                               rtol=1e-5, atol=1e-5)


# --------------------------------------------------------------- stats ---


@settings(max_examples=15, deadline=None)
@given(nb=st.integers(1, 8),
       d=st.sampled_from([4, 32, 512, 2048]),
       seed=st.integers(0, 2**16),
       scale=st.floats(1e-4, 1e3))
def test_bucket_stats_matches_ref(nb, d, seed, scale):
    g = rand(seed, (nb, d), scale)
    got = bucket_stats(g)
    want = ref.bucket_stats_ref(g)
    for a, e in zip(got, want):
        np.testing.assert_allclose(a, e, rtol=1e-5, atol=1e-6 * scale * d)


def test_bucket_stats_constant_bucket():
    g = jnp.full((3, 64), 2.5)
    mn, mx, s, ss, l1 = bucket_stats(g)
    np.testing.assert_allclose(mn, 2.5)
    np.testing.assert_allclose(mx, 2.5)
    np.testing.assert_allclose(s, 2.5 * 64)
    np.testing.assert_allclose(ss, 2.5 * 2.5 * 64, rtol=1e-6)
    np.testing.assert_allclose(l1, 2.5 * 64)


def test_bucket_stats_signs():
    g = jnp.array([[-1.0, 2.0, -3.0, 4.0]])
    mn, mx, s, ss, l1 = bucket_stats(g)
    assert float(mn[0, 0]) == -3.0 and float(mx[0, 0]) == 4.0
    assert float(s[0, 0]) == 2.0 and float(l1[0, 0]) == 10.0


# ------------------------------------------------------------ quantize ---


def sorted_levels(key, nb, s, spread=1.0):
    lv = jax.random.normal(jax.random.PRNGKey(key), (nb, s)) * spread
    return jnp.sort(lv, axis=-1)


@settings(max_examples=15, deadline=None)
@given(nb=st.integers(1, 6), d=st.sampled_from([8, 64, 512]),
       s=st.sampled_from([2, 3, 5, 9]), seed=st.integers(0, 2**16))
def test_quantize_matches_ref(nb, d, s, seed):
    g = rand(seed, (nb, d))
    lv = sorted_levels(seed + 1, nb, s)
    u = jax.random.uniform(jax.random.PRNGKey(seed + 2), (nb, d))
    got = stochastic_quantize(g, lv, u)
    want = ref.stochastic_quantize_ref(g, lv, u)
    assert jnp.array_equal(got, want)
    assert int(jnp.min(got)) >= 0 and int(jnp.max(got)) <= s - 1


def test_quantize_exact_on_levels():
    lv = jnp.array([[-1.0, 0.0, 1.0]])
    g = jnp.array([[-1.0, 0.0, 1.0, 0.5]])
    u = jnp.zeros_like(g)
    idx = stochastic_quantize(g, lv, u)
    # v exactly on a level rounds to it; 0.5 with u=0 < p=0.5 rounds UP.
    assert idx.tolist() == [[0, 1, 2, 2]]


def test_quantize_clamps_outside_range():
    lv = jnp.array([[-1.0, 1.0]])
    g = jnp.array([[-5.0, 5.0]])
    for uval in (0.0, 0.5, 0.999):
        u = jnp.full_like(g, uval)
        idx = stochastic_quantize(g, lv, u)
        assert idx.tolist() == [[0, 1]]


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**16), s=st.sampled_from([3, 5, 9]))
def test_quantize_unbiased_in_expectation(seed, s):
    """E[dequant(Q(v))] == v for v inside the level range (Eq. 7 property)."""
    nb, d = 2, 256
    lv = jnp.sort(jax.random.uniform(jax.random.PRNGKey(seed), (nb, s),
                                     minval=-2.0, maxval=2.0), axis=-1)
    lo = lv[:, :1] + 1e-3
    hi = lv[:, -1:] - 1e-3
    mid = jax.random.uniform(jax.random.PRNGKey(seed + 1), (nb, d))
    g = lo + mid * jnp.maximum(hi - lo, 0.0)

    exp = ref.quantize_expectation_ref(g, lv)
    np.testing.assert_allclose(exp, g, rtol=1e-4, atol=1e-5)


def test_quantize_sampler_monte_carlo_unbiased():
    """The actual sampler's mean converges to v (Eq. 7 unbiasedness)."""
    nb, d, s, n_mc = 1, 128, 5, 400
    lv = jnp.sort(jax.random.uniform(jax.random.PRNGKey(0), (nb, s),
                                     minval=-2.0, maxval=2.0), axis=-1)
    lo, hi = lv[:, :1] + 1e-3, lv[:, -1:] - 1e-3
    mid = jax.random.uniform(jax.random.PRNGKey(1), (nb, d))
    g = lo + mid * (hi - lo)
    keys = jax.random.split(jax.random.PRNGKey(2), n_mc)
    acc = jnp.zeros_like(g)
    for k in keys:
        u = jax.random.uniform(k, (nb, d))
        acc = acc + dequantize(lv, stochastic_quantize(g, lv, u))
    mc = acc / n_mc
    width = float(jnp.max(lv[:, 1:] - lv[:, :-1]))
    np.testing.assert_allclose(mc, g, atol=width * 4 / np.sqrt(n_mc))


def test_dequantize_gathers():
    lv = jnp.array([[0.0, 1.0, 2.0]])
    idx = jnp.array([[2, 0, 1, 1]])
    assert dequantize(lv, idx).tolist() == [[2.0, 0.0, 1.0, 1.0]]
