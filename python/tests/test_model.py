"""L2 correctness: model shapes, flat-param bookkeeping, gradient checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.model import (
    Section,
    TransformerCfg,
    init_flat,
    make_mlp,
    make_transformer,
    mlp_sections,
    param_count,
    registry,
    softmax_xent,
    transformer_sections,
    unflatten,
)

jax.config.update("jax_platform_name", "cpu")


# -------------------------------------------------------- flat params ---


def test_unflatten_roundtrip_order():
    secs = [Section("a", (2, 3), "he"), Section("b", (4,), "zeros"),
            Section("c", (1, 2, 2), "ones")]
    flat = jnp.arange(param_count(secs), dtype=jnp.float32)
    p = unflatten(flat, secs)
    assert p["a"].shape == (2, 3)
    np.testing.assert_array_equal(p["a"].reshape(-1), np.arange(6))
    np.testing.assert_array_equal(p["b"], np.arange(6, 10))
    np.testing.assert_array_equal(p["c"].reshape(-1), np.arange(10, 14))


def test_param_count_mlp():
    secs = mlp_sections(256, [512, 512], 100)
    expect = 256 * 512 + 512 + 512 * 512 + 512 + 512 * 100 + 100
    assert param_count(secs) == expect


def test_init_flat_statistics():
    secs = [Section("w", (1000, 100), "he"), Section("b", (100,), "zeros"),
            Section("g", (100,), "ones")]
    flat = init_flat(secs, jax.random.PRNGKey(0))
    w = flat[: 100000]
    std = float(jnp.std(w))
    assert abs(std - np.sqrt(2.0 / 1000)) < 0.005
    np.testing.assert_array_equal(flat[100000:100100], 0.0)
    np.testing.assert_array_equal(flat[100100:], 1.0)


# ---------------------------------------------------------------- MLP ---


@pytest.fixture(scope="module")
def small_mlp():
    sections, predict, grad = make_mlp(16, [32, 32], 10)
    flat = init_flat(sections, jax.random.PRNGKey(0))
    return sections, predict, grad, flat


def test_mlp_logit_shape(small_mlp):
    sections, predict, grad, flat = small_mlp
    x = jax.random.normal(jax.random.PRNGKey(1), (8, 16))
    (logits,) = predict(flat, x)
    assert logits.shape == (8, 10)
    assert bool(jnp.all(jnp.isfinite(logits)))


def test_mlp_loss_at_init_near_log_c(small_mlp):
    sections, predict, grad, flat = small_mlp
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 10)
    loss, g = grad(flat, x, y)
    assert abs(float(loss) - np.log(10)) < 1.5
    assert g.shape == flat.shape


def test_mlp_grad_descends(small_mlp):
    sections, predict, grad, flat = small_mlp
    x = jax.random.normal(jax.random.PRNGKey(1), (64, 16))
    y = jax.random.randint(jax.random.PRNGKey(2), (64,), 0, 10)
    l0, g = grad(flat, x, y)
    l1, _ = grad(flat - 0.1 * g, x, y)
    assert float(l1) < float(l0)


def test_mlp_grad_finite_difference(small_mlp):
    """Directional finite-difference check of the full flat gradient."""
    sections, predict, grad, flat = small_mlp
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 16))
    y = jax.random.randint(jax.random.PRNGKey(2), (16,), 0, 10)
    loss, g = grad(flat, x, y)
    v = jax.random.normal(jax.random.PRNGKey(3), flat.shape)
    v = v / jnp.linalg.norm(v)
    eps = 1e-3
    lp, _ = grad(flat + eps * v, x, y)
    lm, _ = grad(flat - eps * v, x, y)
    fd = (float(lp) - float(lm)) / (2 * eps)
    an = float(jnp.dot(g, v))
    assert abs(fd - an) < 5e-3 * max(1.0, abs(an))


def test_softmax_xent_perfect_prediction():
    logits = jnp.array([[100.0, 0.0], [0.0, 100.0]])
    labels = jnp.array([0, 1], dtype=jnp.int32)
    assert float(softmax_xent(logits, labels)) < 1e-6


def test_softmax_xent_uniform():
    logits = jnp.zeros((4, 7))
    labels = jnp.array([0, 1, 2, 3], dtype=jnp.int32)
    np.testing.assert_allclose(float(softmax_xent(logits, labels)),
                               np.log(7), rtol=1e-6)


# -------------------------------------------------------- transformer ---


CFG = TransformerCfg(vocab=64, d_model=32, n_heads=2, n_layers=2,
                     seq_len=16, d_ff=64)


@pytest.fixture(scope="module")
def small_lm():
    sections, predict, grad = make_transformer(CFG)
    flat = init_flat(sections, jax.random.PRNGKey(0))
    return sections, predict, grad, flat


def test_lm_logit_shape(small_lm):
    sections, predict, grad, flat = small_lm
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, CFG.seq_len), 0, CFG.vocab)
    (logits,) = predict(flat, tok)
    assert logits.shape == (4, CFG.seq_len, CFG.vocab)


def test_lm_loss_at_init(small_lm):
    sections, predict, grad, flat = small_lm
    tok = jax.random.randint(jax.random.PRNGKey(1), (4, CFG.seq_len + 1), 0, CFG.vocab)
    loss, g = grad(flat, tok)
    assert abs(float(loss) - np.log(CFG.vocab)) < 1.0
    assert g.shape == flat.shape and bool(jnp.all(jnp.isfinite(g)))


def test_lm_causality(small_lm):
    """Changing a future token must not change past logits."""
    sections, predict, grad, flat = small_lm
    tok = jax.random.randint(jax.random.PRNGKey(1), (1, CFG.seq_len), 0, CFG.vocab)
    (l0,) = predict(flat, tok)
    tok2 = tok.at[0, -1].set((tok[0, -1] + 1) % CFG.vocab)
    (l1,) = predict(flat, tok2)
    np.testing.assert_allclose(l0[0, : CFG.seq_len - 1], l1[0, : CFG.seq_len - 1],
                               rtol=1e-5, atol=1e-5)


def test_lm_grad_descends(small_lm):
    sections, predict, grad, flat = small_lm
    tok = jax.random.randint(jax.random.PRNGKey(1), (8, CFG.seq_len + 1), 0, CFG.vocab)
    l0, g = grad(flat, tok)
    l1, _ = grad(flat - 0.5 * g, tok)
    assert float(l1) < float(l0)


def test_transformer_sections_count():
    secs = transformer_sections(CFG)
    # embed + pos + 13 per layer + 3 final
    assert len(secs) == 2 + 13 * CFG.n_layers + 3
    names = [s.name for s in secs]
    assert len(set(names)) == len(names), "section names must be unique"


# ------------------------------------------------------------ registry ---


def test_registry_all_models_build():
    for name, thunk in registry().items():
        md = thunk()
        assert md.name == name
        assert md.kind in ("classifier", "lm")
        assert param_count(md.sections) > 0


def test_registry_param_counts():
    r = registry()
    assert param_count(r["mlp_s"]().sections) == 445_540
    p100 = param_count(r["transformer_100m"]().sections)
    assert 90e6 < p100 < 140e6, f"100M config is {p100:,}"
    pm = param_count(r["transformer_m"]().sections)
    assert 15e6 < pm < 40e6, f"transformer_m is {pm:,}"
