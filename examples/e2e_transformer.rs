//! END-TO-END VALIDATION: train the AOT-compiled JAX/Pallas transformer
//! LM through the full three-layer stack for a few hundred steps.
//!
//!   L1 Pallas kernels (fused dense, matmul) →
//!   L2 JAX transformer fwd/bwd, lowered once to HLO text →
//!   L3 this Rust driver: PJRT execution, ORQ quantization, bit-packed
//!      wire, parameter-server averaging, SGD+momentum — Python is never
//!      on this path.
//!
//! Logs the loss curve to artifacts/results/e2e_transformer_loss.csv and
//! reports wire/comm totals (recorded in EXPERIMENTS.md).
//!
//! Run: `make artifacts && cargo run --release --example e2e_transformer -- [--steps N] [--workers N] [--method orq-5]`

use orq::cli::Args;
use orq::codec::{self, Packing};
use orq::comm::link::Link;
use orq::comm::ps::ParameterServer;
use orq::coordinator::optimizer::SgdMomentum;
use orq::coordinator::schedule::LrSchedule;
use orq::data::corpus::MarkovCorpus;
use orq::quant::bucket::BucketQuantizer;
use orq::runtime::meta::Manifest;
use orq::runtime::Engine;
use orq::tensor::rng::Rng;
use orq::util::csv::CsvWriter;
use orq::util::fmt;

fn main() -> orq::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_parse::<usize>("steps")?.unwrap_or(300);
    let workers = args.get_parse::<usize>("workers")?.unwrap_or(2);
    let method = args.get_or("method", "orq-5").to_string();
    let model_name = args.get_or("model", "transformer_s").to_string();

    println!("loading artifacts (HLO text → PJRT compile)...");
    let manifest = Manifest::load("artifacts")?;
    let engine = Engine::cpu()?;
    let model = engine.load_model(&manifest, &model_name)?;
    let meta = model.meta.clone();
    println!(
        "model {}: {} params, vocab {}, seq {}, batch {} — platform {}",
        meta.name,
        fmt::commas(meta.param_count as u64),
        meta.classes,
        meta.in_dim,
        meta.batch,
        engine.platform()
    );

    // Corpus with learnable bigram structure (loss floor << ln(vocab)).
    let corpus = MarkovCorpus::generate(meta.classes, 200_000, 4, 11);
    println!(
        "corpus: {} tokens, bigram entropy {:.3} nats (uniform = {:.3})",
        fmt::commas(corpus.len() as u64),
        corpus.empirical_bigram_entropy(),
        (meta.classes as f64).ln()
    );

    let quantizer = orq::quant::from_name(&method)?;
    let is_fp = quantizer.num_levels() == 0;
    let bucketq = BucketQuantizer::new(512);
    let schedule = LrSchedule::new(0.05, steps / 20, vec![steps / 2, steps * 3 / 4], 0.1);
    let (mut ps, handles) = ParameterServer::new(workers, Link::ten_gbps());

    let mut params = orq::model::init::init_flat(&meta.sections, &mut Rng::seed_from(1));
    let mut opt = SgdMomentum::new(params.len(), 0.9, 1e-4);
    let mut csv = CsvWriter::create(
        "artifacts/results/e2e_transformer_loss.csv",
        &["step", "loss", "quant_rel_mse", "wire_bytes", "comm_time_s"],
    )?;

    let mut rngs: Vec<Rng> = (0..workers).map(|w| Rng::stream(2, w as u64)).collect();
    let mut qrng = Rng::seed_from(3);
    let t_start = std::time::Instant::now();
    let mut first_loss = None;
    let mut last_loss = 0.0f64;
    for t in 0..steps {
        let bytes_before = ps.meter.total_bytes();
        let time_before = ps.sim_time_s;
        // Workers (driven sequentially on this single-core testbed; the
        // comm path is the real PS channel stack).
        let mut rel_mse_acc = 0.0;
        let mut loss_acc = 0.0;
        for (w, handle) in handles.iter().enumerate() {
            let tokens = corpus.batch(meta.batch, meta.in_dim, &mut rngs[w]);
            let (loss, grad) = model.lm_grad(&params, &tokens)?;
            loss_acc += loss as f64;
            let bytes = if is_fp {
                codec::encode_fp(&grad)
            } else {
                let qg = bucketq.quantize(&grad, quantizer.as_ref(), &mut qrng);
                rel_mse_acc += orq::quant::error::measure(&grad, &qg).rel_mse;
                codec::encode(&qg, &method, Packing::BaseS)
            };
            handle.send_grad(bytes)?;
        }
        // Server: gather, decode, average, broadcast FP.
        let uploads = ps.gather()?;
        let mut avg = vec![0.0f64; params.len()];
        for u in &uploads {
            for (a, v) in avg.iter_mut().zip(codec::decode(u)?.to_flat()) {
                *a += v as f64;
            }
        }
        let avg32: Vec<f32> = avg.iter().map(|a| (*a / workers as f64) as f32).collect();
        ps.broadcast(&codec::encode_fp(&avg32))?;
        for handle in &handles {
            let _ = handle.recv_broadcast()?; // workers would decode this
        }
        opt.step(&mut params, &avg32, schedule.lr_at(t));

        let loss = loss_acc / workers as f64;
        last_loss = loss;
        first_loss.get_or_insert(loss);
        csv.row(&[
            t as f64,
            loss,
            rel_mse_acc / workers as f64,
            (ps.meter.total_bytes() - bytes_before) as f64,
            ps.sim_time_s - time_before,
        ])?;
        if t % 10 == 0 || t + 1 == steps {
            println!(
                "step {t:>4}/{steps}  loss {loss:.4}  ({:.2}s elapsed)",
                t_start.elapsed().as_secs_f64()
            );
        }
    }
    csv.flush()?;

    let first = first_loss.unwrap_or(f64::NAN);
    println!("\n=== e2e summary ===");
    println!("method          : {method} ({} workers)", workers);
    println!("loss            : {first:.4} → {last_loss:.4} (uniform {:.4}, bigram floor {:.4})",
             (meta.classes as f64).ln(), corpus.empirical_bigram_entropy());
    println!("wall time       : {}", fmt::duration(t_start.elapsed().as_secs_f64()));
    println!("wire bytes      : {}", fmt::bytes(ps.meter.total_bytes()));
    println!("sim comm time   : {}", fmt::duration(ps.sim_time_s));
    if !is_fp {
        let ratio = codec::compression_ratio(
            meta.param_count, 512, quantizer.num_levels(), Packing::BaseS, &method);
        println!("uplink ratio    : ×{ratio:.1}");
    }
    println!("loss curve      : artifacts/results/e2e_transformer_loss.csv");
    assert!(last_loss < first, "loss must descend over the run");
    Ok(())
}
