//! Distributed CIFAR(-like) training: 4 worker threads + parameter
//! server, comparing quantization methods under identical budgets — the
//! workload of the paper's Table 2 / Figure 2 in distributed form.
//!
//! Run: `cargo run --release --example distributed_cifar -- [--steps N] [--workers N]`

use orq::bench::print_rows;
use orq::cli::Args;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::util::fmt;

fn main() -> orq::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let steps = args.get_parse::<usize>("steps")?.unwrap_or(300);
    let workers = args.get_parse::<usize>("workers")?.unwrap_or(4);

    let ds = ClassDataset::generate(DatasetSpec::cifar100_like(64));
    let mut rows = Vec::new();
    for method in ["fp", "bingrad-b", "terngrad", "orq-3", "orq-9"] {
        let cfg = TrainConfig {
            model: "mlp:64-192-192-100".into(),
            method: method.into(),
            workers,
            batch: 64 * workers,
            steps,
            lr: 0.08,
            lr_decay_steps: vec![steps / 2, steps * 3 / 4],
            eval_every: 0,
            ..TrainConfig::default()
        };
        let factory = native_backend_factory(&cfg.model)?;
        let out = Trainer::new(cfg, &ds)?.run(factory)?;
        let s = out.summary;
        rows.push(vec![
            method.to_string(),
            format!("{:.2}%", s.test_top1 * 100.0),
            format!("{:.2}%", s.test_top5 * 100.0),
            fmt::bytes(s.total_wire_bytes),
            fmt::duration(s.total_comm_time_s),
        ]);
        println!("{method}: done ({} workers)", workers);
    }
    print_rows(
        &format!("distributed_cifar — {workers} workers, {steps} steps"),
        &["method", "top-1", "top-5", "wire bytes", "sim comm time"],
        &rows,
    );
    println!("\nQuantized methods cut uplink bytes ~20× while staying within a few points of FP.");
    Ok(())
}
