//! Quickstart: train a small classifier with ORQ-quantized gradients and
//! compare against full-precision — the 60-second tour of the public API.
//!
//! Run: `cargo run --release --example quickstart`

use orq::bench::print_rows;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};

fn main() -> orq::Result<()> {
    // 1. A synthetic 100-class task (CIFAR-100 stand-in, DESIGN.md §3).
    let ds = ClassDataset::generate(DatasetSpec::cifar100_like(64));

    // 2. One config per method; everything else identical.
    let mut rows = Vec::new();
    for method in ["fp", "terngrad", "orq-3", "qsgd-5", "orq-5"] {
        let cfg = TrainConfig {
            model: "mlp:64-128-128-100".into(),
            method: method.into(),
            steps: 200,
            batch: 64,
            lr: 0.08,
            lr_decay_steps: vec![120, 170],
            eval_every: 0,
            ..TrainConfig::default()
        };
        // 3. Train through the full coordinator: quantize → encode →
        //    simulated 10 Gbps wire → decode → average → SGD.
        let factory = native_backend_factory(&cfg.model)?;
        let out = Trainer::new(cfg, &ds)?.run(factory)?;
        let s = out.summary;
        rows.push(vec![
            method.to_string(),
            format!("×{:.1}", s.compression_ratio),
            format!("{:.2}%", s.test_top1 * 100.0),
            format!("{:.4}", s.mean_quant_rel_mse),
            orq::util::fmt::bytes(s.total_wire_bytes),
        ]);
    }
    print_rows(
        "quickstart — 200 steps, 1 worker, d=2048",
        &["method", "compression", "top-1", "quant relMSE", "wire bytes"],
        &rows,
    );
    println!("\nNote the ordering: orq-s ≥ qsgd-s/terngrad at equal compression — Theorem 1 at work.");
    Ok(())
}
