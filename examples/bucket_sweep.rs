//! Bucket-size sweep (Table 3 in miniature): accuracy of ORQ-3 vs
//! TernGrad as the bucket size d grows — ORQ should degrade more slowly.
//!
//! Runs on any exchange topology; `--topology ring` exercises the
//! decode-reduce-requantize ring all-reduce end-to-end (2 workers),
//! `--topology hier [--groups N]` the two-level hierarchy (4 workers in
//! 2 groups by default), where intra-hop + leader requantization adds
//! extra error on top of the bucket effect, and `--topology sharded-ps
//! [--shards S] [--staleness K]` the sharded/bounded-staleness parameter
//! server (per-shard byte counters printed after each sweep).
//!
//! Run: `cargo run --release --example bucket_sweep -- [--steps N]
//!       [--topology ps|ring|hier|sharded-ps] [--workers N] [--groups N]
//!       [--shards S] [--staleness K] [--threads N] [--pool true|false]`
//!
//! `--threads N` runs the parallel codec per node (the big-bucket rows
//! shard well); `--pool false` reverts to per-round scoped threads.
//! `--trace FILE` records the final (largest-bucket, last-method) run at
//! `fine` level and writes the Chrome trace + metrics JSON pair plus a
//! per-step `FILE.series.csv` — the CI smoke job uploads these as
//! artifacts, and the CI determinism job runs the sweep twice with the
//! same seed and requires the series CSV and the metrics model-drift
//! section to match byte-for-byte.

use orq::bench::print_rows;
use orq::cli::Args;
use orq::comm::Topology;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};

fn main() -> orq::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.check_known(&[
        "steps", "topology", "workers", "groups", "shards", "staleness", "threads", "pool",
        "trace",
    ])?;
    let steps = args.get_parse::<usize>("steps")?.unwrap_or(250);
    let topology = args.get_parse::<Topology>("topology")?.unwrap_or_default();
    let workers = args.get_parse::<usize>("workers")?.unwrap_or(match topology {
        Topology::Ring => 2,
        Topology::Hier => 4,
        Topology::Ps => 1,
        Topology::ShardedPs => 2,
    });
    let groups = args
        .get_parse::<usize>("groups")?
        .unwrap_or(if topology == Topology::Hier { 2.min(workers) } else { 1 });
    let shards = args
        .get_parse::<usize>("shards")?
        .unwrap_or(if topology == Topology::ShardedPs { 2 } else { 1 });
    let staleness = args.get_parse::<usize>("staleness")?.unwrap_or(0);
    let threads = args.get_parse::<usize>("threads")?.unwrap_or(1);
    let pool = args.get_parse::<bool>("pool")?.unwrap_or(true);
    let trace_path = args.get("trace").map(str::to_string);

    let ds = ClassDataset::generate(DatasetSpec::cifar10_like(64));
    let buckets = [128usize, 512, 2048, 8192, 32768];
    let methods = ["terngrad", "orq-3"];
    let mut rows = Vec::new();
    for method in methods {
        let mut row = vec![method.to_string()];
        let mut last_shard_bytes: Option<Vec<u64>> = None;
        for &d in &buckets {
            // Trace exactly one run per invocation (the last sweep cell)
            // so the artifact stays small and deterministic in shape.
            let traced = trace_path.is_some()
                && method == *methods.last().unwrap()
                && d == *buckets.last().unwrap();
            let cfg = TrainConfig {
                model: "mlp:64-192-192-10".into(),
                dataset: "cifar10".into(),
                method: method.into(),
                steps,
                workers,
                batch: 64,
                bucket_size: d,
                eval_every: 0,
                lr: 0.08,
                lr_decay_steps: vec![steps / 2, steps * 3 / 4],
                topology,
                groups,
                shards,
                staleness,
                threads,
                pool,
                trace_level: if traced {
                    orq::obs::TraceLevel::Fine
                } else {
                    orq::obs::TraceLevel::Off
                },
                ..TrainConfig::default()
            };
            let factory = native_backend_factory(&cfg.model)?;
            let out = Trainer::new(cfg, &ds)?.run(factory)?;
            row.push(format!("{:.2}", out.summary.test_top1 * 100.0));
            if traced {
                let path = trace_path.as_deref().expect("traced implies a path");
                let obs = out.obs.as_ref().expect("traced runs carry events");
                std::fs::write(path, orq::obs::chrome_trace_json(&obs.events).dump())?;
                let mjson = orq::obs::metrics_json(&out.series, &obs.registry);
                std::fs::write(format!("{path}.metrics.json"), mjson.dump())?;
                // Per-step series CSV: the CI determinism job runs this
                // example twice with identical seeds and compares the two
                // files byte-for-byte.
                out.series.write_csv(&format!("{path}.series.csv"))?;
                println!(
                    "{method}: traced d={d} run → {path} ({} events)",
                    obs.events.len()
                );
            }
            last_shard_bytes = out.shard_bytes;
        }
        rows.push(row);
        let shape = match topology {
            Topology::Hier => format!("{topology} ({workers} workers, {groups} groups)"),
            Topology::ShardedPs => format!(
                "{topology} ({workers} workers, {shards} shards, staleness {staleness})"
            ),
            _ => format!("{topology} ({workers} workers)"),
        };
        println!("{method}: swept {} bucket sizes on {shape}", buckets.len());
        if let Some(sb) = &last_shard_bytes {
            let parts: Vec<String> = sb.iter().map(|b| b.to_string()).collect();
            println!("{method}: per-shard wire bytes at d={} → [{}]",
                     buckets.last().unwrap(), parts.join(", "));
        }
    }
    let labels: Vec<String> = buckets.iter().map(|b| b.to_string()).collect();
    let mut header = vec!["method"];
    header.extend(labels.iter().map(|s| s.as_str()));
    print_rows(
        &format!("bucket_sweep ({topology}) — CIFAR-10(-like) top-1 (%) vs bucket size d"),
        &header,
        &rows,
    );
    println!("\nSmaller buckets → finer level tables → higher accuracy; ORQ-3 is more resilient to large d (Table 3).");
    Ok(())
}
