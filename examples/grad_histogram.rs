//! Figure-1-style gradient histograms: quantize one real mid-training
//! gradient with every method and dump the normalized distributions.
//!
//! Run: `cargo run --release --example grad_histogram -- [--out DIR]`

use orq::cli::Args;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::metrics::histogram::Histogram;
use orq::quant::bucket::BucketQuantizer;
use orq::tensor::rng::Rng;

fn main() -> orq::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    let outdir = args.get_or("out", "artifacts/results").to_string();

    // Warm up a model so the gradient has realistic (non-init) structure.
    let ds = ClassDataset::generate(DatasetSpec::cifar100_like(64));
    let cfg = TrainConfig {
        model: "mlp:64-192-192-100".into(),
        method: "fp".into(),
        steps: 80,
        batch: 64,
        eval_every: 0,
        lr_decay_steps: vec![],
        ..TrainConfig::default()
    };
    let factory = native_backend_factory(&cfg.model)?;
    let out = Trainer::new(cfg, &ds)?.run(&factory)?;

    let mut backend = factory(0);
    let mut grad = vec![0.0f32; out.params.len()];
    let mut rng = Rng::seed_from(5);
    let batch = ds.train_batch(64, &mut rng);
    backend.loss_grad(&out.params, &batch, &mut grad);

    std::fs::create_dir_all(&outdir)?;
    let h_fp = Histogram::sigma_range(&grad, 2.5, 81);
    h_fp.write_csv(&format!("{outdir}/hist_fp.csv"))?;
    println!("FP gradient: {} elements, histogram → {outdir}/hist_fp.csv", grad.len());

    let bq = BucketQuantizer::new(2048);
    for method in ["qsgd-9", "orq-9", "linear-9", "bingrad-pb", "bingrad-b", "terngrad"] {
        let q = orq::quant::from_name(method)?;
        let qg = bq.quantize(&grad, q.as_ref(), &mut rng);
        let mut h = Histogram::new(h_fp.lo, h_fp.hi, 81);
        h.fill(&qg.dequantize());
        h.write_csv(&format!("{outdir}/hist_{method}.csv"))?;
        let e = orq::quant::error::measure(&grad, &qg);
        println!(
            "{method:<11} relMSE={:.5}  cosine={:.5}  hist occupancy={:.1}%",
            e.rel_mse,
            e.cosine,
            h.occupancy() * 100.0
        );
    }
    println!("\nPlot the CSVs (center vs normalized) to reproduce Figure 1.");
    Ok(())
}
