//! ImageNet-like distributed run with gradient clipping — the paper's
//! §5.2 protocol (4 workers, d = 512, clip 2.5σ, warmup), with series
//! CSVs for plotting Figure 3.
//!
//! Scale the exchange with `--shards S` / `--staleness K` (either one
//! switches the topology to the sharded/bounded-staleness parameter
//! server unless `--topology` says otherwise); sharded runs print the
//! per-shard wire-byte counters and the staleness histogram.
//!
//! Run: `cargo run --release --example imagenet_distributed --
//!       [--steps N] [--method orq-5] [--out DIR]
//!       [--topology ps|ring|hier|sharded-ps] [--shards S] [--staleness K]
//!       [--threads N] [--pool true|false]`
//!
//! `--threads N` shards the codec per node; `--pool false` falls back to
//! the per-round scoped threads (bit-identical results, slower steady
//! state).

use orq::cli::Args;
use orq::comm::Topology;
use orq::config::TrainConfig;
use orq::coordinator::trainer::{native_backend_factory, Trainer};
use orq::data::synth::{ClassDataset, DatasetSpec};
use orq::util::fmt;

fn main() -> orq::Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    args.check_known(&[
        "steps", "method", "out", "topology", "shards", "staleness", "threads", "pool",
    ])?;
    let steps = args.get_parse::<usize>("steps")?.unwrap_or(250);
    let method = args.get_or("method", "orq-5").to_string();
    let outdir = args.get_or("out", "artifacts/results").to_string();
    let shards = args.get_parse::<usize>("shards")?.unwrap_or(1);
    let staleness = args.get_parse::<usize>("staleness")?.unwrap_or(0);
    let threads = args.get_parse::<usize>("threads")?.unwrap_or(1);
    let pool = args.get_parse::<bool>("pool")?.unwrap_or(true);
    let topology = args.get_parse::<Topology>("topology")?.unwrap_or(
        if shards > 1 || staleness > 0 { Topology::ShardedPs } else { Topology::Ps },
    );

    let mut spec = DatasetSpec::imagenet_like(128);
    spec.classes = 100;
    spec.train_n = 8192;
    spec.test_n = 2048;
    let ds = ClassDataset::generate(spec);

    let cfg = TrainConfig {
        model: "mlp:128-256-256-100".into(),
        dataset: "imagenet".into(),
        method: method.clone(),
        workers: 4,
        batch: 256, // paper: 256 total split across 4 workers
        steps,
        lr: 0.08,
        momentum: 0.9,
        weight_decay: 1e-4,
        lr_decay_steps: vec![steps / 3, steps * 2 / 3], // paper: epochs 30/60 of 90
        lr_decay: 0.1,
        warmup_steps: if method == "fp" { 0 } else { steps / 18 },
        bucket_size: 512,
        clip_factor: if method == "fp" { None } else { Some(2.5) },
        seed: 7,
        eval_every: (steps / 10).max(1),
        quantize_downlink: false,
        topology,
        groups: 1,
        // Passed through verbatim: an explicit --shards/--staleness that
        // conflicts with --topology is rejected by TrainConfig::validate,
        // never silently overridden.
        shards,
        staleness,
        error_feedback: false,
        threads,
        pool,
        overlap: false,
        sections: None,
        stream_sections: false,
        byte_budget: None,
        budget_schedule: None,
        trace_level: orq::obs::TraceLevel::Off,
        links: orq::config::LinkConfig::default(),
    };
    println!(
        "imagenet_distributed: {method}, 4 workers, d=512, clip 2.5σ, {steps} steps, \
         topology {topology}, {threads} codec thread(s), {}",
        if pool { "pooled" } else { "scoped threads" }
    );
    let factory = native_backend_factory(&cfg.model)?;
    let out = Trainer::new(cfg, &ds)?.run(factory)?;
    let s = &out.summary;
    println!("top-1 {:.2}%  top-5 {:.2}%  quant relMSE {:.4}", s.test_top1 * 100.0,
             s.test_top5 * 100.0, s.mean_quant_rel_mse);
    println!("wire {}  sim comm {}", fmt::bytes(s.total_wire_bytes),
             fmt::duration(s.total_comm_time_s));
    if let Some(sb) = &out.shard_bytes {
        let parts: Vec<String> = sb.iter().map(|b| fmt::bytes(*b)).collect();
        println!("per-shard wire bytes: [{}]", parts.join(", "));
        let st = &out.comm.staleness;
        println!(
            "staleness: window applied age max {} ({} cold start rounds of {})",
            st.max_age, st.cold_rounds, st.rounds
        );
    }

    std::fs::create_dir_all(&outdir)?;
    out.series.write_csv(&format!("{outdir}/imagenet_{method}_series.csv"))?;
    out.series.write_eval_csv(&format!("{outdir}/imagenet_{method}_eval.csv"))?;
    println!("series → {outdir}/imagenet_{method}_series.csv");
    Ok(())
}
